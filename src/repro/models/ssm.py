"""State-space & recurrent blocks: Mamba2 (zamba2) and mLSTM/sLSTM (xLSTM).

Mamba2 uses the chunkwise SSD formulation (intra-chunk quadratic einsums +
lax.scan over chunk states) for train/prefill and the O(1) recurrent state
update for decode -- this is what makes ``long_500k`` runnable for the
SSM/hybrid archs.  mLSTM uses the parallel (decay-matrix) form for
train/prefill and the matrix-memory recurrence for decode; sLSTM is a
strict lax.scan over time (its recurrence is not parallelizable).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.layers import he_init


def _heads_spec(n, shards):
    return "model" if (shards and n % shards == 0) else None


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

def init_mamba2(rng, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    heads = d_in // 64                      # mamba2 convention: headdim 64
    ks = jax.random.split(rng, 8)
    return {
        "in_x": he_init(ks[0], (d, d_in)),
        "in_z": he_init(ks[1], (d, d_in)),
        "in_b": he_init(ks[2], (d, s.n_groups * s.d_state)),
        "in_c": he_init(ks[3], (d, s.n_groups * s.d_state)),
        "in_dt": he_init(ks[4], (d, heads)),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(jnp.float32),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "conv": he_init(ks[5], (s.d_conv, d_in), s.d_conv),
        "norm": layers.init_rms(ks[6], d_in),
        "out": he_init(ks[7], (d_in, d), d_in),
    }


def mamba2_specs(cfg, model_shards):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    heads = d_in // 64
    hs = _heads_spec(heads, model_shards)
    ds = _heads_spec(d_in, model_shards)
    return {
        "in_x": P(None, ds), "in_z": P(None, ds),
        "in_b": P(None, None), "in_c": P(None, None),
        "in_dt": P(None, hs), "dt_bias": P(hs), "a_log": P(hs),
        "d_skip": P(hs), "conv": P(None, ds), "norm": P(ds),
        "out": P(ds, None),
    }


def _causal_conv(x, w, state=None):
    """x: [b,t,c]; w: [k,c] depthwise.  state: [b,k-1,c] for decode."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
        new_state = xp[:, -(k - 1):] if k > 1 else None
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xp[:, -(k - 1):]
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out), new_state


def mamba2_block(p, x, cfg, *, state=None):
    """x: [b,t,d].  state: {"ssm": [b,H,64,ds], "conv": [b,k-1,d_in]} or None.

    Returns (y [b,t,d], new_state or None).
    """
    s = cfg.ssm
    b, t, d = x.shape
    d_in = s.expand * d
    H = d_in // 64
    ds = s.d_state

    z = x @ p["in_z"]
    xc = x @ p["in_x"]
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv(xc, p["conv"], conv_state)
    xh = xc.reshape(b, t, H, 64)
    B = (x @ p["in_b"]).reshape(b, t, s.n_groups, ds)
    C = (x @ p["in_c"]).reshape(b, t, s.n_groups, ds)
    B = jnp.repeat(B, H // s.n_groups, axis=2)               # [b,t,H,ds]
    C = jnp.repeat(C, H // s.n_groups, axis=2)
    dt = jax.nn.softplus((x @ p["in_dt"]).astype(jnp.float32)
                         + p["dt_bias"])                      # [b,t,H]
    a = -jnp.exp(p["a_log"])                                  # [H] < 0
    decay = dt * a                                            # log-decay

    if state is None:
        y, _ = _ssd_chunked(xh, B, C, dt, decay, s.chunk)
        new_state = None
    elif t > 1:
        # prefill: chunked scan, keep the final SSM state for decode
        y, final = _ssd_chunked(xh, B, C, dt, decay, s.chunk)
        new_state = {"ssm": final, "conv": new_conv}
    else:
        # recurrent decode (t small, typically 1):
        st = state["ssm"].astype(jnp.float32)                 # [b,H,64,ds]
        ys = []
        for i in range(t):
            g = jnp.exp(decay[:, i])[..., None, None]         # [b,H,1,1]
            upd = jnp.einsum("bh,bhp,bhn->bhpn", dt[:, i],
                             xh[:, i].astype(jnp.float32),
                             B[:, i].astype(jnp.float32))
            st = g * st + upd
            ys.append(jnp.einsum("bhpn,bhn->bhp", st,
                                 C[:, i].astype(jnp.float32)))
        y = jnp.stack(ys, axis=1).astype(x.dtype)             # [b,t,H,64]
        new_state = {"ssm": st, "conv": new_conv}
    y = y + xh * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, t, d_in) * jax.nn.silu(z)
    y = layers.rms_norm(p["norm"], y, cfg.norm_eps)
    return y @ p["out"], new_state


def _ssd_chunked(xh, B, C, dt, decay, chunk):
    """Chunkwise SSD scan.  xh: [b,t,H,p], B/C: [b,t,H,n], dt/decay [b,t,H]."""
    b, t, H, p = xh.shape
    n = B.shape[-1]
    c = min(chunk, t)
    if t % c:
        # ragged tail: zero-pad (dt=0 -> identity state transition, zero
        # contribution), outputs sliced back below
        pad = c - t % c
        z = lambda a: jnp.concatenate(
            [a, jnp.zeros((b, pad) + a.shape[2:], a.dtype)], axis=1)
        xh, B, C, dt, decay = map(z, (xh, B, C, dt, decay))
        y, final = _ssd_chunked(xh, B, C, dt, decay, chunk)
        return y[:, :t], final
    nc = t // c
    r = lambda a: a.reshape((b, nc, c) + a.shape[2:])
    xh, B, C, dt, decay = map(r, (xh, B, C, dt, decay))
    xf = (xh * dt[..., None]).astype(jnp.float32)             # dt-weighted
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    seg = jnp.cumsum(decay, axis=2)                           # [b,nc,c,H]
    # intra-chunk (quadratic within chunk):
    rel = seg[:, :, :, None] - seg[:, :, None]                # [b,nc,i,j,H]
    mask = jnp.tril(jnp.ones((c, c), bool))
    gamma = jnp.where(mask[None, None, ..., None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bgihn,bgjhn->bgijh", Cf, Bf) * gamma
    y_intra = jnp.einsum("bgijh,bgjhp->bgihp", scores, xf)
    # chunk summaries -> inter-chunk state scan
    tail = seg[:, :, -1:, :] - seg                            # decay to end
    s_chunk = jnp.einsum("bgjhn,bgjhp->bghnp",
                         Bf * jnp.exp(tail)[..., None], xf)   # [b,nc,H,n,p]
    g_chunk = jnp.exp(seg[:, :, -1])                          # [b,nc,H]

    def scan_body(carry, inp):
        s_c, g_c = inp
        new = carry * g_c[..., None, None] + s_c
        return new, carry                                      # emit prev

    init = jnp.zeros((b, H, n, p), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_body, init,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(g_chunk, 1, 0)))
    prev = jnp.moveaxis(prev_states, 0, 1)                    # [b,nc,H,n,p]
    y_inter = jnp.einsum("bgihn,bghnp->bgihp",
                         Cf * jnp.exp(seg)[..., None], prev)
    y = (y_intra + y_inter).reshape(b, t, H, p)
    # final carry is the state *after* the last chunk, transposed to the
    # decode layout [b, H, p, n]
    return y.astype(xh.dtype), jnp.moveaxis(final, -1, -2)


def mamba2_state_init(cfg, b, dtype=jnp.float32):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // 64
    return {"ssm": jnp.zeros((b, H, 64, s.d_state), dtype),
            "conv": jnp.zeros((b, s.d_conv - 1, d_in), dtype)}


def mamba2_state_specs(cfg, model_shards, batch_axes):
    d_in = cfg.ssm.expand * cfg.d_model
    H = d_in // 64
    hs = _heads_spec(H, model_shards)
    return {"ssm": P(batch_axes, hs, None, None),
            "conv": P(batch_axes, None, _heads_spec(d_in, model_shards))}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (parallel + recurrent) and sLSTM (sequential scan)
# ---------------------------------------------------------------------------

def init_mlstm(rng, cfg):
    x = cfg.xlstm
    d = cfg.d_model
    d_in = int(x.proj_factor * d)
    H = cfg.n_heads
    hd = d_in // H
    ks = jax.random.split(rng, 9)
    return {
        "up": he_init(ks[0], (d, 2 * d_in)),
        "conv": he_init(ks[1], (x.conv_kernel, d_in), x.conv_kernel),
        "wq": he_init(ks[2], (d_in, d_in)),
        "wk": he_init(ks[3], (d_in, d_in)),
        "wv": he_init(ks[4], (d_in, d_in)),
        "wi": he_init(ks[5], (d_in, H)),
        "wf": he_init(ks[6], (d_in, H)),
        "fb": jnp.full((H,), 3.0, jnp.float32),   # forget bias (keep)
        "norm": layers.init_rms(ks[7], d_in),
        "down": he_init(ks[8], (d_in, d), d_in),
    }


def mlstm_specs(cfg, model_shards):
    x = cfg.xlstm
    d_in = int(x.proj_factor * cfg.d_model)
    ds = _heads_spec(d_in, model_shards)
    hs = _heads_spec(cfg.n_heads, model_shards)
    return {"up": P(None, None), "conv": P(None, ds),
            "wq": P(None, ds), "wk": P(None, ds), "wv": P(None, ds),
            "wi": P(None, hs), "wf": P(None, hs), "fb": P(hs),
            "norm": P(ds), "down": P(ds, None)}


def mlstm_block(p, x, cfg, *, state=None):
    """x: [b,t,d] -> (y, new_state).  state: {"C":[b,H,hd,hd], "n":[b,H,hd],
    "m":[b,H], "conv":[b,k-1,d_in]}."""
    xc_cfg = cfg.xlstm
    b, t, d = x.shape
    d_in = int(xc_cfg.proj_factor * d)
    H = cfg.n_heads
    hd = d_in // H
    up = x @ p["up"]
    u, z = up[..., :d_in], up[..., d_in:]
    conv_state = None if state is None else state["conv"]
    uc, new_conv = _causal_conv(u, p["conv"], conv_state)
    q = (uc @ p["wq"]).reshape(b, t, H, hd)
    k = (uc @ p["wk"]).reshape(b, t, H, hd) / math.sqrt(hd)
    v = (u @ p["wv"]).reshape(b, t, H, hd)
    i_pre = (uc @ p["wi"]).astype(jnp.float32)                # [b,t,H]
    f_pre = (uc @ p["wf"]).astype(jnp.float32) + p["fb"]

    if state is None:
        y = _mlstm_parallel(q, k, v, i_pre, f_pre)
        new_state = None
    elif t > 1:
        # prefill: parallel output + closed-form final (C, n, m)
        y = _mlstm_parallel(q, k, v, i_pre, f_pre)
        logf = jax.nn.log_sigmoid(f_pre)
        cf = jnp.cumsum(logf, axis=1)                          # [b,t,H]
        w_log = cf[:, -1:] - cf + i_pre                        # [b,t,H]
        m = jnp.max(w_log, axis=1)                             # [b,H]
        w = jnp.exp(w_log - m[:, None])
        C = jnp.einsum("bth,bthp,bthq->bhpq", w,
                       k.astype(jnp.float32), v.astype(jnp.float32))
        n = jnp.einsum("bth,bthp->bhp", w, k.astype(jnp.float32))
        new_state = {"C": C, "n": n, "m": m, "conv": new_conv}
    else:
        C = state["C"].astype(jnp.float32)
        n = state["n"].astype(jnp.float32)
        m = state["m"].astype(jnp.float32)
        ys = []
        for s_ in range(t):
            logf = jax.nn.log_sigmoid(f_pre[:, s_])
            m_new = jnp.maximum(logf + m, i_pre[:, s_])
            fg = jnp.exp(logf + m - m_new)[..., None, None]
            ig = jnp.exp(i_pre[:, s_] - m_new)[..., None, None]
            kv = jnp.einsum("bhp,bhq->bhpq", k[:, s_].astype(jnp.float32),
                            v[:, s_].astype(jnp.float32))
            C = fg * C + ig * kv
            n = fg[..., 0] * n + ig[..., 0] * k[:, s_].astype(jnp.float32)
            m = m_new
            num = jnp.einsum("bhpq,bhp->bhq", C,
                             q[:, s_].astype(jnp.float32))
            den = jnp.maximum(
                jnp.abs(jnp.einsum("bhp,bhp->bh", n,
                                   q[:, s_].astype(jnp.float32))),
                1.0)[..., None]
            ys.append((num / den).astype(x.dtype))
        y = jnp.stack(ys, axis=1)                              # [b,t,H,hd]
        new_state = {"C": C, "n": n, "m": m, "conv": new_conv}
    y = y.reshape(b, t, d_in)
    y = layers.rms_norm(p["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return y @ p["down"], new_state


def _mlstm_parallel(q, k, v, i_pre, f_pre):
    """Parallel (decay-matrix) mLSTM: quadratic in t, used for train/prefill."""
    b, t, H, hd = q.shape
    logf = jax.nn.log_sigmoid(f_pre)                           # [b,t,H]
    cf = jnp.cumsum(logf, axis=1)
    # D[i,j] = exp(cf_i - cf_j + i_j) for j <= i (stabilized)
    rel = cf[:, :, None] - cf[:, None] + i_pre[:, None]        # [b,i,j,H]
    mask = jnp.tril(jnp.ones((t, t), bool))
    rel = jnp.where(mask[None, ..., None], rel, -jnp.inf)
    m = jnp.maximum(jnp.max(rel, axis=2, keepdims=True), 0.0)  # stabilizer
    D = jnp.exp(rel - m)                                       # [b,i,j,H]
    scores = jnp.einsum("bihd,bjhd->bijh", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * D
    norm = jnp.maximum(jnp.abs(jnp.sum(scores, axis=2)),
                       jnp.exp(-m[:, :, 0]))                   # [b,i,H]
    y = jnp.einsum("bijh,bjhd->bihd", scores, v.astype(jnp.float32))
    return (y / norm[..., None]).astype(q.dtype)


def mlstm_state_init(cfg, b, dtype=jnp.float32):
    x = cfg.xlstm
    d_in = int(x.proj_factor * cfg.d_model)
    H = cfg.n_heads
    hd = d_in // H
    return {"C": jnp.zeros((b, H, hd, hd), dtype),
            "n": jnp.zeros((b, H, hd), dtype),
            "m": jnp.zeros((b, H), dtype),
            "conv": jnp.zeros((b, x.conv_kernel - 1, d_in), dtype)}


def mlstm_state_specs(cfg, model_shards, batch_axes):
    H = cfg.n_heads
    hs = _heads_spec(H, model_shards)
    d_in = int(cfg.xlstm.proj_factor * cfg.d_model)
    return {"C": P(batch_axes, hs, None, None),
            "n": P(batch_axes, hs, None), "m": P(batch_axes, hs),
            "conv": P(batch_axes, None, _heads_spec(d_in, model_shards))}


def init_slstm(rng, cfg):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(rng, 7)
    return {
        "wx": he_init(ks[0], (d, 4 * d)),                      # i,f,z,o
        "wr": he_init(ks[1], (H, hd, 4 * hd), hd),             # block recurrent
        "fb": jnp.full((H,), 3.0, jnp.float32),
        "norm": layers.init_rms(ks[2], d),
        "up": he_init(ks[3], (d, int(4 * d / 3) * 2)),
        "down": he_init(ks[4], (int(4 * d / 3), d), int(4 * d / 3)),
    }


def slstm_specs(cfg, model_shards):
    H = cfg.n_heads
    hs = _heads_spec(H, model_shards)
    return {"wx": P(None, None), "wr": P(hs, None, None), "fb": P(hs),
            "norm": P(None), "up": P(None, None), "down": P(None, None)}


def slstm_block(p, x, cfg, *, state=None):
    """Sequential sLSTM + gated FFN.  state: {"c","n","h":[b,H,hd],"m":[b,H]}."""
    b, t, d = x.shape
    H = cfg.n_heads
    hd = d // H
    xg = (x @ p["wx"]).reshape(b, t, H, 4 * hd).astype(jnp.float32)

    if state is None:
        c0 = jnp.zeros((b, H, hd), jnp.float32)
        h0 = jnp.zeros((b, H, hd), jnp.float32)
        n0 = jnp.ones((b, H, hd), jnp.float32)
        m0 = jnp.zeros((b, H), jnp.float32)
    else:
        c0, h0 = state["c"].astype(jnp.float32), state["h"].astype(jnp.float32)
        n0, m0 = state["n"].astype(jnp.float32), state["m"].astype(jnp.float32)

    def step(carry, xt):
        c, n, h, m = carry
        rec = jnp.einsum("bhp,hpq->bhq", h, p["wr"])           # [b,H,4hd]
        g = xt + rec
        ih, fh, zh, oh = jnp.split(g, 4, axis=-1)
        i_pre = jnp.mean(ih, axis=-1)                          # scalar gates/head
        f_pre = jnp.mean(fh, axis=-1) + p["fb"]
        m_new = jnp.maximum(jax.nn.log_sigmoid(f_pre) + m, i_pre)
        fg = jnp.exp(jax.nn.log_sigmoid(f_pre) + m - m_new)[..., None]
        ig = jnp.exp(i_pre - m_new)[..., None]
        z = jnp.tanh(zh)
        o = jax.nn.sigmoid(oh)
        c = fg * c + ig * z
        n = fg * n + ig
        h = o * (c / jnp.maximum(n, 1.0))
        return (c, n, h, m_new), h

    (c, n, h, m), hs = jax.lax.scan(
        step, (c0, n0, h0, m0), jnp.moveaxis(xg, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, t, d).astype(x.dtype)
    y = layers.rms_norm(p["norm"], y, cfg.norm_eps)
    # gated FFN
    ff = int(4 * d / 3)
    uv = y @ p["up"]
    y = (jax.nn.silu(uv[..., :ff]) * uv[..., ff:]) @ p["down"]
    new_state = None if state is None else {"c": c, "n": n, "h": h, "m": m}
    return y, new_state


def slstm_state_init(cfg, b, dtype=jnp.float32):
    H = cfg.n_heads
    hd = cfg.d_model // H
    z = lambda *s: jnp.zeros(s, dtype)
    return {"c": z(b, H, hd), "n": jnp.ones((b, H, hd), dtype),
            "h": z(b, H, hd), "m": z(b, H)}


def slstm_state_specs(cfg, model_shards, batch_axes):
    hs = _heads_spec(cfg.n_heads, model_shards)
    return {"c": P(batch_axes, hs, None), "n": P(batch_axes, hs, None),
            "h": P(batch_axes, hs, None), "m": P(batch_axes, hs)}
