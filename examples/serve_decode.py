"""Serve a reduced model: batched prefill + autoregressive decode with the
framework's KV-cache serving path (same code the decode_32k/long_500k
dry-run cells lower).

Serves straight from a flat-state checkpoint: the trained ``FlatState``
buffer (``state_layout="flat"``) is handed to
``specs.serve_params_from_flat`` and the model runs on ``unflatten``
slice VIEWS of the buffer -- no per-leaf tree is ever assembled
(zero-copy checkpoint -> serving).

    PYTHONPATH=src python examples/serve_decode.py
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import flatbuf
from repro.core.topology import single_device_topology
from repro.launch import specs
from repro.models import build

cfg = configs.get_smoke("zamba2_2p7b")      # hybrid SSM: O(1) decode state
topo = single_device_topology()
built = build.build_model(cfg, topo)
params_tree = built.init_params(jax.random.PRNGKey(0))

# what a flat-state training run checkpoints: ONE [P, n_pad] buffer
# (P = 1 edge here).  Serving slices views out of it directly.
ckpt = flatbuf.from_tree(
    jax.tree.map(lambda v: v[None], params_tree), batch_dims=1)
params = specs.serve_params_from_flat(built, topo, ckpt)
probe = jax.tree.leaves(params_tree)[0]
np.testing.assert_array_equal(np.asarray(jax.tree.leaves(params)[0]),
                              np.asarray(probe))
shardings = specs.serve_param_shardings(built, topo, ckpt)
print(f"serving {ckpt.layout.n} params from a FlatState view "
      f"(n_pad={ckpt.layout.n_pad}, "
      f"buffer sharding={jax.tree.leaves(shardings)[0].spec})")

B, PROMPT, GEN = 4, 24, 16
prompts = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0,
                             cfg.vocab, jnp.int32)

logits, cache = built.prefill(params, {"tokens": prompts},
                              max_len=PROMPT + GEN)
tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
decode = jax.jit(built.decode_step)
out = [tok]
t0 = time.time()
for _ in range(GEN - 1):
    logits, cache = decode(params, cache, tok)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out.append(tok)
dt = time.time() - t0
gen = jnp.concatenate(out, axis=1)
print(f"prompts {prompts.shape} -> generated {gen.shape}")
print(f"decode: {(GEN-1)*B/dt:.1f} tok/s (batch {B}, CPU, reduced config)")
print("sample token ids:", gen[0][:10].tolist())
assert bool(jnp.isfinite(logits).all())
print("OK")
