"""Multi-device (8 forced host CPUs) checks, run in subprocesses so the
rest of the suite keeps the default single-device jax runtime.

  * fsdp_lift custom-vjp regime == replicated regime (toy model, exact);
  * engine-level fsdp == replicated for dense and MoE configs
    (statistical criterion: sign methods amplify ULP noise to +-mu).

The distributed-vs-oracle and transport/state-layout trajectory parity
checks moved into the parity matrix: tests/test_parity_matrix.py +
helpers/parity_matrix_check.py.
"""
import pathlib
import subprocess
import sys

import pytest

HELPERS = pathlib.Path(__file__).parent / "helpers"
SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


def _run(script: str, timeout=900):
    env = {"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
           "HOME": "/tmp"}
    r = subprocess.run([sys.executable, str(HELPERS / script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, (
        f"{script} failed:\nSTDOUT:\n{r.stdout[-4000:]}\n"
        f"STDERR:\n{r.stderr[-4000:]}")
    return r.stdout


@pytest.mark.slow
def test_fsdp_lift_equals_replicated_toy():
    out = _run("fsdp_toy_check.py")
    assert "fsdp path OK" in out


@pytest.mark.slow
def test_engine_fsdp_equals_replicated():
    out = _run("engine_fsdp_check.py")
    assert "ENGINE FSDP == REPLICATED OK" in out
