"""Fault-tolerance demo: checkpointed training that survives a chaos
schedule -- client kill, straggler demotion, heartbeat loss, an injected
nan-loss (restore + replay from the newest checkpoint), and a simulated
process crash (automatic resume).

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp

from repro import configs
from repro.core import clients as vclients
from repro.core import hier
from repro.core.topology import single_device_topology
from repro.launch.train import RunCfg, run_training
from repro.runtime.chaos import ChaosEvent, FaultInjector

cfg = configs.get_smoke("stablelm_3b")
topo = single_device_topology()
algo = hier.AlgoConfig(method="dc_hier_signsgd", mu=2e-3, t_e=4, rho=0.3,
                       compute_dtype=jnp.float32,
                       clients=vclients.ClientConfig(count=2))

with tempfile.TemporaryDirectory() as ckpt:
    run = RunCfg(steps=12, batch_per_device=4, seq_len=64,
                 ckpt_dir=ckpt, ckpt_every=4, log_every=4)
    # One explicit chaos schedule drives everything (events at step s
    # apply before step s; the same schedule form feeds the parity
    # matrix's chaos cells and `launch.train --chaos SEED`):
    inj = FaultInjector([
        ChaosEvent(3, "client", 0, 0, 1),      # virtual client dies
        ChaosEvent(5, "recover", 0, 0, 1),     # ...and rejoins
        ChaosEvent(6, "straggler", 0, 0, 0),   # demoted to abstention
        ChaosEvent(8, "recover", 0, 0, 0),
        ChaosEvent(9, "nan"),                  # numeric blow-up: the
        # driver restores the newest checkpoint and replays -- batches
        # are cursor-addressable and membership replays from the
        # schedule, so the rerun is deterministic
    ])
    state, hist = run_training(cfg, topo, algo, run, fault_injector=inj)
    assert min(h["live"] for h in hist) < 1.0, "churn should be visible"
    assert hist[-1]["live"] == 1.0, "everyone recovered"
    print(f"\nphase 1 done at step {hist[-1]['step']} "
          f"(loss {hist[-1]['loss']:.3f}); simulating crash + restart...")
    # "crash": rerun with a longer horizon -- run_training resumes from
    # the newest intact checkpoint automatically
    run2 = RunCfg(steps=18, batch_per_device=4, seq_len=64,
                  ckpt_dir=ckpt, ckpt_every=4, log_every=4)
    state, hist2 = run_training(cfg, topo, algo, run2)
    assert hist2[0]["step"] >= 8, "should resume from a checkpoint"
    print(f"resumed at step {hist2[0]['step']}, finished at "
          f"{hist2[-1]['step']} (loss {hist2[-1]['loss']:.3f})")
print("OK")
