"""Cloud sync schedule: WHEN a cloud aggregate is issued vs committed.

The paper's cloud tier is a synchronous barrier -- every T_E local steps
all edges stop, the cross-pod mean lands, and the anchors refresh before
anyone steps again.  At deployment scale the cloud round-trip dominates
wall-clock, so the schedule of that barrier becomes its own layer: a
round boundary splits into an *issue* phase (snapshot the edge models
and start the cross-pod mean) and a *commit* phase (apply an aggregate
that finished its flight), and the only question is how many boundaries
separate the two.

``CloudSchedule`` answers it with a single integer ``lag``:

  * ``lag=0`` (``mode="sync"``) -- issue and commit at the SAME
    boundary: today's behavior, bitwise-preserved.  No staged state.
  * ``lag=1`` (``mode="overlap"``) -- the aggregate issued at boundary
    t is committed at boundary t+1: edges run round t's local sign
    steps against their LOCAL models while the mean is in flight, and
    the DC ``delta`` / SCAFFOLD ``corr_*`` / MTGC ``eta`` anchors
    refresh at the *committed* (one-round-stale) aggregate.  The
    in-flight aggregate lives in a staged slot (``TrainState.agg_next``
    in the distributed step, ``FedState.w_inflight`` in the ``ref_fed``
    oracle) -- the same staging shape as DC's ``anchor_staleness`` /
    ``delta_next`` knob, generalized to the model itself.

Commit weights are pinned to ISSUE-time membership: the mean that left
at boundary t lands unchanged at boundary t+1 even if pods died or
recovered mid-flight (the ``edge_weights_agg`` oracle hook carries the
issue-time weights under churn).

Both ``core.hier`` (the jitted step) and ``core.ref_fed`` (the python
oracle) consume the SAME schedule object, so the sync/overlap choice is
a property of this layer -- never re-derived per local-step path or per
launcher.  ``commit`` is layout-agnostic: it only swaps references, so
pytrees, ``flatbuf.FlatState`` buffers and python model trees all ride
through unchanged.
"""
from __future__ import annotations

import dataclasses

CLOUD_OVERLAP_MODES = ("sync", "overlap")


@dataclasses.dataclass(frozen=True)
class CloudSchedule:
    """The cloud tier's issue->commit latency, in round boundaries.

    ``lag=0`` is the synchronous barrier; ``lag=1`` overlaps one round
    of local stepping with the aggregate's flight.  A zero-latency
    commit (lag=0) routed through the overlap machinery collapses to
    the sync trajectory -- property-tested in
    tests/test_ref_fed_overlap.py.
    """
    lag: int = 0

    def __post_init__(self):
        if self.lag not in (0, 1):
            raise ValueError(
                f"CloudSchedule lag must be 0 (sync) or 1 (overlap), "
                f"got {self.lag}")

    @classmethod
    def from_mode(cls, mode: str) -> "CloudSchedule":
        if mode not in CLOUD_OVERLAP_MODES:
            raise ValueError(
                f"unknown cloud_overlap mode {mode!r} (choose from "
                f"{', '.join(CLOUD_OVERLAP_MODES)})")
        return cls(lag=0 if mode == "sync" else 1)

    @property
    def mode(self) -> str:
        return "sync" if self.lag == 0 else "overlap"

    @property
    def staged(self) -> bool:
        """Whether a staged (in-flight) aggregate slot exists at all."""
        return self.lag > 0

    def commit(self, issued, staged):
        """One round boundary: ``(model_to_run_on, new_staged)``.

        ``issued`` is the aggregate computed AT this boundary from the
        current edge models (with this boundary's membership weights);
        ``staged`` is the slot holding the aggregate issued ``lag``
        boundaries ago (``None`` when nothing is staged).  Sync commits
        ``issued`` immediately and leaves the slot untouched; overlap
        commits the staged aggregate and stages ``issued`` in its
        place.
        """
        if self.lag == 0:
            return issued, staged
        return staged, issued
