"""Scratch: 8-host-device bitwise parity of the fused flat-buffer transport.

Same trajectory (seeds, batches, straggler masks) must produce bitwise
identical edge models for transport in {ag_packed, ar_int8, fused} --
the transports differ only in wire format, never in votes (ties -> +1).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import hier
from repro.core.topology import Topology

Pn, Dn, Mn = 2, 2, 2
mesh = Mesh(np.array(jax.devices()).reshape(Pn, Dn, Mn),
            ("pod", "data", "model"))
topo = Topology(mesh=mesh, pod_axis="pod")


def loss_fn(params, batch, rng):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


kw = jax.random.PRNGKey(0)
# mixed leaf shapes: model-sharded matrix, odd-minor bias (33 % 32 != 0)
w0 = {"w": jax.random.normal(kw, (16, 64)) * 0.3,
      "b": jnp.zeros((33,)),
      "w2": jax.random.normal(jax.random.fold_in(kw, 1), (64, 33)) * 0.3}


def loss2(params, batch, rng):
    h = batch["x"] @ params["w"]
    pred = h @ params["w2"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


specs = {"w": P(None, "model"), "b": P(None), "w2": P("model", None)}

T_E, ROUNDS, B = 3, 3, 8
rb = jax.random.PRNGKey(7)
xs = jax.random.normal(rb, (ROUNDS * T_E, Pn, Dn, B, 16))
w_true = jax.random.normal(jax.random.PRNGKey(9), (Pn, 16, 33))
ys = jnp.einsum("spdbi,pio->spdbo", xs, w_true)

full_mask = jnp.ones((Pn, Dn))
straggler = jnp.asarray([[1.0, 0.0], [1.0, 1.0]])


def run(method, transport, mask, error_feedback=False):
    algo = hier.AlgoConfig(method=method, mu=5e-3, t_e=T_E, rho=1.0,
                           transport=transport,
                           error_feedback=error_feedback,
                           compute_dtype=jnp.float32,
                           master_dtype=jnp.float32,
                           delta_dtype=jnp.float32)
    bundle = hier.ModelBundle(loss=loss2, compute_specs=specs,
                              master_specs=specs)
    init_fn, step = hier.make_hier_step(topo, algo, bundle)
    state = init_fn(w0, jax.random.PRNGKey(1))
    ew = jnp.full((Pn,), 1.0 / Pn)
    dw = jnp.full((Pn, Dn), 1.0 / Dn)
    jstep = jax.jit(step)
    for s in range(ROUNDS * T_E):
        batch = {"train": {"x": xs[s], "y": ys[s]},
                 "anchor": {"x": xs[s - s % T_E], "y": ys[s - s % T_E]}}
        state, _ = jstep(state, batch, ew, dw, mask)
    return {k: np.asarray(v) for k, v in state.params.items()}


cases = [(m, mk, ef)
         for m in ("hier_signsgd", "dc_hier_signsgd")
         for mk, ef in ((full_mask, False), (straggler, False))]
cases.append(("dc_hier_signsgd", full_mask, True))       # EF path

for method, mask, ef in cases:
    ref = run(method, "ag_packed", mask, ef)
    for transport in ("ar_int8", "fused"):
        got = run(method, transport, mask, ef)
        for k in ref:
            same = np.array_equal(ref[k], got[k])
            tag = (f"{method}/{transport}/mask={int(mask.sum())}"
                   f"/ef={int(ef)}/{k}")
            assert same, (tag, np.max(np.abs(ref[k] - got[k])))
    print(f"{method:16s} mask={int(mask.sum())} ef={int(ef)} parity OK")

print("fused transport parity OK")
