"""8-host-device parity matrix: every supported (method x transport x
state_layout x regime) train-step combo on a 2x2x2 (pod, data, model)
mesh, checked bitwise against each other, against the ``ref_fed`` paper
oracle, and (FSDP regime) against the replicated regime.

Replaces the old ad-hoc ``fused_parity_check.py`` and
``multidev_oracle_check.py`` scratch scripts -- the shared problem and
runners live in ``parity_harness.py``.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import pathlib
import sys
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

import parity_harness as H  # noqa: E402
from repro.core.topology import Topology  # noqa: E402

Pn, Dn, Mn = 2, 2, 2
mesh = Mesh(np.array(jax.devices()).reshape(Pn, Dn, Mn),
            ("pod", "data", "model"))
topo = Topology(mesh=mesh, pod_axis="pod")
problem = H.make_problem(Pn, Dn)

# ---- full matrix, full quorum: bitwise cross parity per method --------
refs, ew = {}, None
for method, transport, layout in H.matrix_cells():
    got, ew = H.run_hier(topo, problem, method, transport, layout)
    ref = refs.setdefault(method, got)     # first cell = (ag_packed, tree)
    H.assert_trees_equal(ref, got, f"{method}/{transport}/{layout}")
    print(f"{method:16s} {transport:10s} {layout:5s} parity OK")

# ---- paper oracle (rng-free methods) ----------------------------------
for method in ("hier_signsgd", "dc_hier_signsgd", "scaffold_hier_signsgd",
               "mtgc_hier_signsgd", "hier_sgd"):
    agg = H.aggregate(refs[method], ew)
    oracle = H.run_oracle(problem, method)
    H.assert_trees_equal(agg, oracle, f"oracle/{method}", exact=False,
                         atol=1e-5)
    print(f"{method:16s} == ref_fed oracle OK")

# ---- straggler quorum mask --------------------------------------------
straggler = [[True, False], [True, True]]
maskf = np.asarray(straggler, np.float32)
for method in ("hier_signsgd", "dc_hier_signsgd"):
    ref = None
    for transport in H.SIGN_TRANSPORTS:
        for layout in H.LAYOUTS:
            got, ew = H.run_hier(topo, problem, method, transport, layout,
                                 mask=maskf)
            ref = got if ref is None else ref
            H.assert_trees_equal(
                ref, got, f"mask/{method}/{transport}/{layout}")
    oracle = H.run_oracle(problem, method, mask=straggler)
    H.assert_trees_equal(H.aggregate(ref, ew), oracle,
                         f"mask-oracle/{method}", exact=False, atol=1e-5)
    print(f"{method:16s} straggler-mask parity + oracle OK")

# ---- error feedback / momentum (beyond-paper, replicated) -------------
for kw in ({"error_feedback": True}, {"momentum": 0.9}):
    ref = None
    for transport in ("ag_packed", "fused"):
        for layout in H.LAYOUTS:
            got, _ = H.run_hier(topo, problem, "dc_hier_signsgd",
                                transport, layout, **kw)
            ref = got if ref is None else ref
            H.assert_trees_equal(
                ref, got, f"{kw}/{transport}/{layout}")
    print(f"dc_hier_signsgd  {kw} parity OK")

# ---- virtual clients: K=4 sampled + weighted (tree/flat, fused) -------
# each physical data slice hosts 4 virtual clients (voter axis 2*4=8),
# Bernoulli(0.5) per-round participation from the pinned (seed, round)
# scheme, unequal integer |D_qk| vote weights; the fused transport runs
# the weighted popcount on the merged client axis under the SHARDED
# flat layout (model=2) and must stay bitwise vs the per-leaf path
cc = H.client_cfg(Pn, Dn, 4, "sampled_weighted")
ref_c, ew = None, None
for transport, layout in (("ag_packed", "tree"), ("fused", "tree"),
                          ("fused", "flat"), ("ar_int8", "flat")):
    got, ew = H.run_hier(topo, problem, "dc_hier_signsgd", transport,
                         layout, clients=cc)
    ref_c = got if ref_c is None else ref_c
    H.assert_trees_equal(ref_c, got, f"clients/{transport}/{layout}")
oracle = H.run_oracle(problem, "dc_hier_signsgd", clients=cc)
H.assert_trees_equal(H.aggregate(ref_c, ew), oracle, "clients-oracle",
                     exact=False, atol=1e-5)
print("dc_hier_signsgd  K=4 sampled-weighted client cell OK")

# ---- streamed client sweep on the 8-device mesh -----------------------
# the same K=4 sampled-weighted cell run with mode="stream" (the in-step
# fori_loop over clients accumulating the persistent integer tally) must
# be bitwise the merged reference on every transport x layout ABOVE --
# including the fused cell under the model-SHARDED flat layout, where
# the per-rank tally accumulates in the shard_map bucket coordinate
# space and the one data-axis all-gather happens after the client loop
import dataclasses  # noqa: E402
sc = dataclasses.replace(cc, mode="stream")
for transport, layout in (("ag_packed", "tree"), ("fused", "tree"),
                          ("fused", "flat"), ("ar_int8", "flat")):
    got, _ = H.run_hier(topo, problem, "dc_hier_signsgd", transport,
                        layout, clients=sc)
    H.assert_trees_equal(ref_c, got, f"stream/{transport}/{layout}")
got, _ = H.run_hier(topo, problem, "hier_sgd", clients=sc)
merged_m, _ = H.run_hier(topo, problem, "hier_sgd", clients=cc)
H.assert_trees_equal(merged_m, got, "stream/hier_sgd/mean")
print("dc_hier_signsgd  K=4 streamed sweep == merged OK (incl. sharded)")

# ---- drift-correction methods: K=4 sampled-weighted cell --------------
# one sampled-weighted cell per new pre-sign-correction method: merged
# bitwise across transports x layouts (incl. the fused program under the
# model-SHARDED flat layout, where the per-client control variates live
# as voter-axis FlatState slots), streamed sweep bitwise vs merged on
# the sharded fused cell, and the cloud-aggregated model pinned against
# the grown ref_fed oracle
for method in ("scaffold_hier_signsgd", "mtgc_hier_signsgd"):
    ref_m, ew = None, None
    for transport, layout in (("ag_packed", "tree"), ("fused", "flat")):
        got, ew = H.run_hier(topo, problem, method, transport, layout,
                             clients=cc)
        ref_m = got if ref_m is None else ref_m
        H.assert_trees_equal(ref_m, got,
                             f"corr/{method}/{transport}/{layout}")
    got, _ = H.run_hier(topo, problem, method, "fused", "flat",
                        clients=sc)
    H.assert_trees_equal(ref_m, got, f"corr-stream/{method}")
    oracle = H.run_oracle(problem, method, clients=cc)
    H.assert_trees_equal(H.aggregate(ref_m, ew), oracle,
                         f"corr-oracle/{method}", exact=False, atol=1e-5)
    print(f"{method:22s} K=4 sampled-weighted cell OK (incl. sharded)")

# ---- chaos churn cell: K=2 sampled-weighted under a fault schedule ----
# the deterministic churn schedule (client kill, straggler demotion,
# heartbeat loss, POD 1 kill + recovery -- the multi-pod path exercises
# a non-trivial edge_weights renormalization and the closing-round
# edge_weights_agg) composed with Bernoulli(0.5) participation and
# unequal |D_qk| weights: bitwise across transports/layouts/modes
# (incl. the model-SHARDED fused flat cell) and pinned vs the grown
# ref_fed oracle driven by the same compiled membership arrays (the
# P=1 fast tier is EXACT; here the P=2 cloud aggregation associates
# the weighted sum differently -> the usual multi-device oracle atol)
ccc = H.client_cfg(Pn, Dn, 2, "sampled_weighted")
inj = H.chaos_injector(Pn, Dn, 2, problem["t_e"])
arrays = H.chaos_arrays(problem, ccc, inj)
assert any(a.edge_weights[1] == 0.0 for a in arrays), "pod kill missing"
ref_h, _ = H.run_hier_chaos(topo, problem, "dc_hier_signsgd",
                            clients=ccc, arrays=arrays)
for transport, layout, mode in (("fused", "tree", "merged"),
                                ("fused", "flat", "stream"),
                                ("ar_int8", "flat", "merged")):
    ccm = ccc if mode == "merged" else dataclasses.replace(ccc,
                                                           mode="stream")
    got, _ = H.run_hier_chaos(topo, problem, "dc_hier_signsgd",
                              transport, layout, clients=ccm,
                              arrays=arrays)
    H.assert_trees_equal(ref_h, got,
                         f"chaos/{transport}/{layout}/{mode}")
oracle = H.run_oracle_chaos(problem, "dc_hier_signsgd", ccc, arrays)
H.assert_trees_equal(H.aggregate(ref_h, arrays[-1].edge_weights),
                     oracle, "chaos-oracle", exact=False, atol=1e-5)
print("dc_hier_signsgd  K=2 sampled-weighted churn cell OK (pod kill)")

# ---- overlapped cloud tier on the 8-device mesh -----------------------
# cloud_overlap="overlap" under the SAME churn schedule: pod 1 dies at
# step t_e+2 -- i.e. WHILE the aggregate issued at the step-t_e boundary
# is in flight -- and recovers one round later.  The commit weights are
# pinned to issue-time membership (edge_weights_agg), so the in-flight
# mean lands unchanged; cells stay bitwise across transports x layouts x
# modes (incl. the model-SHARDED fused flat agg_next slot) and the
# closing aggregate matches the extended oracle's w_inflight at the
# usual multi-device atol
ref_o, _ = H.run_hier_chaos(topo, problem, "dc_hier_signsgd",
                            clients=ccc, arrays=arrays,
                            cloud_overlap="overlap")
for transport, layout, mode in (("fused", "tree", "merged"),
                                ("fused", "flat", "stream"),
                                ("ar_int8", "flat", "merged")):
    ccm = ccc if mode == "merged" else dataclasses.replace(ccc,
                                                           mode="stream")
    got, _ = H.run_hier_chaos(topo, problem, "dc_hier_signsgd",
                              transport, layout, clients=ccm,
                              arrays=arrays, cloud_overlap="overlap")
    H.assert_trees_equal(ref_o, got,
                         f"overlap-chaos/{transport}/{layout}/{mode}")
oracle = H.run_oracle_chaos(problem, "dc_hier_signsgd", ccc, arrays,
                            cloud_overlap="overlap")
H.assert_trees_equal(H.aggregate(ref_o, arrays[-1].edge_weights),
                     oracle, "overlap-chaos-oracle", exact=False,
                     atol=1e-5)
for method in ("hier_signsgd", "scaffold_hier_signsgd",
               "mtgc_hier_signsgd"):
    got, ew = H.run_hier(topo, problem, method, clients=cc,
                         cloud_overlap="overlap")
    oracle = H.run_oracle(problem, method, clients=cc,
                          cloud_overlap="overlap")
    H.assert_trees_equal(H.aggregate(got, ew), oracle,
                         f"overlap-oracle/{method}", exact=False,
                         atol=1e-5)
print("dc_hier_signsgd  overlap churn-in-flight cell OK (pod kill)")

# ---- clustered edge assignment under intra-edge skew ------------------
# K=2 virtual clients per slice with per-client Dirichlet(0.25) target
# mixtures (make_problem(alpha_client=...)); mean-embedding sketches +
# the deterministic balanced clustering (data.cluster) regroup the
# fleet's 8 virtual clients into the 2 pods by data similarity.  The
# distributed step runs the regrouped ROW BLOCKS (clients.regroup_
# clients on the carve coordinates, incl. the model-SHARDED fused flat
# cell and the streamed sweep) and must stay bitwise across cells and
# EXACT vs the grown oracle fed the SAME permutation through
# ref_fed.regroup_client_data -- the two regrouping implementations pin
# each other
skewp = H.make_problem(Pn, Dn, clients=2, alpha_client=0.25)
order = H.clustered_assignment(skewp, 2)
assert not np.array_equal(order, np.arange(len(order))), \
    "clustering is a no-op permutation; nothing is exercised"
movedp = H.regroup_problem(skewp, order)
cck = H.client_cfg(Pn, Dn, 2, "full")
ref_a, ew = None, None
for transport, layout, mode in (("ag_packed", "tree", "merged"),
                                ("fused", "flat", "merged"),
                                ("fused", "flat", "stream")):
    ccm = cck if mode == "merged" else dataclasses.replace(cck,
                                                           mode="stream")
    got, ew = H.run_hier(topo, movedp, "dc_hier_signsgd", transport,
                         layout, clients=ccm)
    ref_a = got if ref_a is None else ref_a
    H.assert_trees_equal(ref_a, got,
                         f"clustered/{transport}/{layout}/{mode}")
oracle = H.run_oracle(skewp, "dc_hier_signsgd", clients=cck,
                      assignment=order)
H.assert_trees_equal(H.aggregate(ref_a, ew), oracle, "clustered-oracle",
                     exact=True)
H.assert_trees_equal(oracle,
                     H.run_oracle(movedp, "dc_hier_signsgd", clients=cck),
                     "clustered-slice-vs-permute", exact=True)
print("dc_hier_signsgd  clustered edge-assignment cell OK (intra-edge "
      "skew)")

# ---- uneven TP leaves (odd hid): padded-shard flat layout -------------
# both weight matrices model-shard unevenly (65 % 2 != 0) -- the flat
# cells run the padded-block layout (LeafSlot.shard_pad) and must stay
# bitwise identical to the tree-state reference on the same trajectory
uneven = H.make_problem(Pn, Dn, hid=H.UNEVEN_HID)
ref_u = None
for transport in H.SIGN_TRANSPORTS:
    for layout in H.LAYOUTS:
        got, _ = H.run_hier(topo, uneven, "dc_hier_signsgd", transport,
                            layout)
        ref_u = got if ref_u is None else ref_u
        H.assert_trees_equal(ref_u, got,
                             f"uneven/{transport}/{layout}")
print("dc_hier_signsgd  uneven-TP-leaf parity OK (padded shards)")

# ---- FSDP regime (tree layout) vs replicated --------------------------
for method in ("hier_signsgd", "dc_hier_signsgd", "hier_sgd"):
    got, _ = H.run_hier(topo, problem, method, regime="fsdp")
    H.assert_trees_equal(refs[method], got, f"fsdp/{method}",
                         exact=False, atol=1e-6)
    print(f"{method:16s} fsdp == replicated OK")

print("parity matrix OK")
