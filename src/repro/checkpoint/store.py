"""Fault-tolerant checkpoint store (atomic, integrity-checked, keep-k).

Layout per checkpoint:
    <dir>/step_<N>.tmp-<pid>/   (written)   ->  <dir>/step_<N>/  (renamed)
        manifest.json           {step, tree structure, per-file crc32}
        arrays.npz              flat leaves (key = leaf path)
    <dir>/LATEST                text file with the newest complete step

Atomicity: everything is written into a tmp dir and os.rename'd into
place (POSIX-atomic), LATEST updated last; a crash mid-write can never
corrupt an existing checkpoint.  ``restore_latest`` verifies CRCs and
falls back to the previous checkpoint if the newest is damaged --
together with the driver's retry loop this is the node-failure story
(DESIGN.md Sec. 7).

Flat state (``core.flatbuf.FlatState``, used by ``state_layout="flat"``):
a FlatState node is saved as its single buffer array plus a
``manifest["flat_state"]`` entry recording the FlatLayout (slot table
with per-slot LOGICAL global shapes, n/n_pad, buffer dtype, model-shard
count, per-slot shard dims and uneven ``shard_pad`` tails).  Restore
converts both ways: a flat checkpoint loads into a tree-state ``like``
(the buffer is sliced per slot -- sharded slots reassemble their
per-bucket blocks along ``shard_dim`` and drop the uneven zero tail)
and a tree checkpoint loads into a flat-state ``like`` (the leaves are
assembled into the buffer at their slot offsets, zero-padded block per
bucket for sharded slots, copies into every bucket otherwise) -- in
both directions only the real coordinates transfer; tile/tail/shard
padding is don't-care.  The slot table is validated against the
``like`` layout; when the tables differ but every logical leaf agrees
(same keys, same global shapes -- e.g. an old copy-style manifest for a
leaf the padded-shard layout now keeps sharded, or a different shard
count), restore transparently goes through the tree form.  Anything
else raises naming the offending leaf and field.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flatbuf

PyTree = Any
SEP = "/"


def _is_prng_key(x) -> bool:
    try:
        return jnp.issubdtype(x.dtype, jax.dtypes.prng_key)
    except Exception:
        return False


def _is_flat(x) -> bool:
    return isinstance(x, flatbuf.FlatState)


def _key_of(path) -> str:
    return SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _leaf_keys(layout: flatbuf.FlatLayout) -> list[str]:
    """Per-slot leaf path keys (relative to the FlatState node), in slot
    order -- the names the leaves would have been saved under in tree
    form, so conversion can match by KEY, not position."""
    skeleton = layout.treedef.unflatten(list(range(len(layout.slots))))
    flat, _ = jax.tree_util.tree_flatten_with_path(skeleton)
    keys = [None] * len(layout.slots)
    for path, idx in flat:
        keys[idx] = _key_of(path)
    return keys


def _layout_meta(fs: flatbuf.FlatState) -> dict:
    """JSON-able FlatLayout record stored in the manifest."""
    lay = fs.layout
    return {
        "n": lay.n,
        "n_pad": lay.n_pad,
        "shards": lay.shards,
        "dtype": str(np.dtype(lay.dtype)) if np.dtype(lay.dtype).kind != "V"
        else "bfloat16",
        "batch_dims": fs.batch_dims,
        "slots": [{"key": key, "shape": list(s.shape),
                   "global_shape": list(s.global_shape(lay.shards)),
                   "dtype": str(np.dtype(s.dtype))
                   if np.dtype(s.dtype).kind != "V" else "bfloat16",
                   "size": s.size, "padded": s.padded, "offset": s.offset,
                   "shard_dim": s.shard_dim, "shard_pad": s.shard_pad}
                  for key, s in zip(_leaf_keys(lay), lay.slots)],
    }


def _meta_global_shape(slot: dict, shards: int) -> tuple[int, ...]:
    """LOGICAL leaf shape a saved slot stores (old manifests lack the
    explicit ``global_shape``/``shard_pad`` fields -- derive it)."""
    if "global_shape" in slot:
        return tuple(slot["global_shape"])
    local = tuple(slot["shape"])
    sd = slot.get("shard_dim")
    if sd is None:
        return local
    sp = slot.get("shard_pad", 0)
    return local[:sd] + (local[sd] * shards - sp,) + local[sd + 1:]


def _slot_mismatch(meta: dict, like_fs: flatbuf.FlatState) -> str | None:
    """First difference between the saved slot table and the target's,
    as an actionable per-leaf message (None when they match exactly)."""
    layout = like_fs.layout
    if meta.get("shards", 1) != layout.shards:
        return (f"shards: checkpoint has {meta.get('shards', 1)}, target "
                f"layout has {layout.shards}")
    if meta["n_pad"] != layout.n_pad:
        return (f"n_pad: checkpoint has {meta['n_pad']}, target layout "
                f"has {layout.n_pad}")
    if meta["batch_dims"] != like_fs.batch_dims:
        return (f"batch_dims: checkpoint has {meta['batch_dims']}, "
                f"target has {like_fs.batch_dims}")
    if len(meta["slots"]) != len(layout.slots):
        return (f"slot count: checkpoint has {len(meta['slots'])} leaves, "
                f"target layout has {len(layout.slots)}")
    for key, slot, saved in zip(_leaf_keys(layout), layout.slots,
                                meta["slots"]):
        if saved["key"] != key:
            return (f"leaf {key!r}: checkpoint slot at the same position "
                    f"is keyed {saved['key']!r} (renamed/reordered leaf)")
        for field, ours, theirs in (
                ("shape", list(slot.shape), list(saved["shape"])),
                ("size", slot.size, saved["size"]),
                ("padded", slot.padded, saved["padded"]),
                ("offset", slot.offset, saved["offset"]),
                ("shard_dim", slot.shard_dim, saved.get("shard_dim")),
                ("shard_pad", slot.shard_pad, saved.get("shard_pad", 0))):
            if ours != theirs:
                return (f"leaf {key!r}, field {field!r}: checkpoint has "
                        f"{theirs!r}, target layout has {ours!r}")
    return None


def _check_batch(arr_shape, like_fs: flatbuf.FlatState, where: str):
    """The saved buffer's leading (batch) dims must match the target's."""
    want = tuple(like_fs.buf.shape[:like_fs.batch_dims])
    got = tuple(arr_shape[:like_fs.batch_dims])
    if got != want:
        raise IOError(
            f"flat-state layout mismatch at {where!r}: checkpoint batch "
            f"shape {got}, target expects {want}")


def _flatten(tree: PyTree):
    """Storable dict: FlatState -> its buffer array (+ flat_state meta)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=_is_flat)
    out, flat_meta = {}, {}
    for path, leaf in flat:
        key = _key_of(path)
        if _is_flat(leaf):
            flat_meta[key] = _layout_meta(leaf)
            leaf = leaf.buf
        if _is_prng_key(leaf):
            leaf = jax.random.key_data(leaf)   # typed key -> uint32 payload
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":              # bfloat16: no numpy dtype --
            arr = np.asarray(jnp.asarray(leaf).astype(jnp.float32))
        out[key] = arr                         # restore casts back
    return out, flat_meta


def save(ckpt_dir: str | pathlib.Path, step: int, tree: PyTree,
         keep: int = 3) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:010d}"
    tmp = ckpt_dir / f"step_{step:010d}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    arrays, flat_meta = _flatten(tree)
    npz_path = tmp / "arrays.npz"
    np.savez(npz_path, **arrays)
    crc = zlib.crc32(npz_path.read_bytes())
    manifest = {
        "step": step,
        "crc32": crc,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
    }
    if flat_meta:
        manifest["flat_state"] = flat_meta
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    (ckpt_dir / "LATEST.tmp").write_text(str(step))
    os.rename(ckpt_dir / "LATEST.tmp", ckpt_dir / "LATEST")
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: pathlib.Path, keep: int):
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob(
        "step_*") if p.is_dir() and ".tmp" not in p.name)
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:010d}", ignore_errors=True)


def available_steps(ckpt_dir: str | pathlib.Path) -> list[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    return sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob(
        "step_*") if p.is_dir() and ".tmp" not in p.name)


def _verify(path: pathlib.Path) -> bool:
    try:
        manifest = json.loads((path / "manifest.json").read_text())
        crc = zlib.crc32((path / "arrays.npz").read_bytes())
        return crc == manifest["crc32"]
    except Exception:
        return False


def _expand_flat_buf(buf: np.ndarray, meta: dict) -> dict:
    """Saved flat buffer -> {slot key: LOGICAL leaf array}, per its own
    manifest metadata: sharded slots reassemble their per-bucket blocks
    along ``shard_dim`` and drop the uneven ``shard_pad`` zero tail;
    per-bucket copies collapse to bucket 0 (bit-identical)."""
    bd = meta["batch_dims"]
    batch = tuple(buf.shape[:bd])
    shards = meta.get("shards", 1)
    bp = meta["n_pad"] // shards
    out = {}
    for slot in meta["slots"]:
        local = tuple(slot["shape"])
        sd = slot.get("shard_dim")
        off, size = slot["offset"], slot["size"]
        if sd is None:
            out[slot["key"]] = buf[..., off:off + size].reshape(
                batch + local)
            continue
        blocks = [buf[..., m * bp + off:m * bp + off + size
                      ].reshape(batch + local) for m in range(shards)]
        full = np.concatenate(blocks, axis=bd + sd)
        extent = _meta_global_shape(slot, shards)[sd]
        if full.shape[bd + sd] != extent:      # drop the shard zero tail
            full = full[(slice(None),) * (bd + sd) + (slice(0, extent),)]
        out[slot["key"]] = full
    return out


def _pack_flat_buf(arrs: dict, like_fs: flatbuf.FlatState,
                   where: str) -> np.ndarray:
    """{slot key: LOGICAL leaf array} -> the target layout's buffer:
    zero-padded block per bucket for sharded slots, copies into every
    bucket otherwise.  Raises naming the leaf on a missing key or a
    global-shape mismatch."""
    lay = like_fs.layout
    bd = like_fs.batch_dims
    batch = None
    np_dtype = (np.float32 if np.dtype(lay.dtype).kind == "V"
                else np.dtype(lay.dtype))
    parts = []
    for rel, slot in zip(_leaf_keys(lay), lay.slots):
        k = where + SEP + rel
        if rel not in arrs:
            raise IOError(
                f"checkpoint is missing leaf {k!r} for flat-state "
                f"target {where!r}")
        arr = arrs[rel]
        want = slot.global_shape(lay.shards)
        if tuple(arr.shape[bd:]) != want:
            raise IOError(
                f"flat-state leaf {k!r} has shape {arr.shape}, slot "
                f"expects {want} after {bd} batch dims")
        _check_batch(arr.shape, like_fs, k)
        if batch is None:
            batch = arr.shape[:bd]
        parts.append((slot, arr))
    buf = np.zeros(batch + (lay.n_pad,), np_dtype)
    bp = lay.bucket_pad
    for slot, arr in parts:
        if slot.shard_dim is None:
            # per-bucket copy: every model shard holds the full leaf
            flat = arr.reshape(batch + (slot.size,))
            blocks = [flat] * lay.shards
        else:
            ax = bd + slot.shard_dim
            if slot.shard_pad:                 # uneven: zero shard tail
                pads = [(0, 0)] * arr.ndim
                pads[ax] = (0, slot.shard_pad)
                arr = np.pad(np.asarray(arr), pads)
            blocks = [b.reshape(batch + (slot.size,)) for b in np.split(
                arr, lay.shards, axis=ax)]
        for m, blk in enumerate(blocks):
            off = m * bp + slot.offset
            buf[..., off:off + slot.size] = blk
    return buf


def _assemble_flat(data, key: str, like_fs: flatbuf.FlatState) -> np.ndarray:
    """Tree checkpoint -> flat run: pack saved leaves into the buffer.

    Leaves are matched BY KEY (``<key>/<leaf path>`` as the tree save
    wrote them), so a renamed or restructured leaf raises instead of
    silently landing in another slot's coordinates.
    """
    arrs = {rel: data[key + SEP + rel]
            for rel in _leaf_keys(like_fs.layout)
            if key + SEP + rel in data}
    return _pack_flat_buf(arrs, like_fs, key)


def _convert_flat(buf, meta: dict, key: str, like_fs: flatbuf.FlatState,
                  mismatch: str) -> np.ndarray:
    """Flat checkpoint whose layout differs from the flat target: go
    through the tree form.  Exact when every logical leaf agrees (same
    keys / global shapes) -- e.g. an old copy-style manifest restored
    into the padded-shard layout, or a different shard count; anything
    else raises with the slot-level mismatch AND the leaf-level cause.
    """
    arrs = _expand_flat_buf(np.asarray(buf), meta)
    try:
        return _pack_flat_buf(arrs, like_fs, key)
    except IOError as e:
        raise IOError(
            f"flat-state layout mismatch at {key!r} ({mismatch}); "
            f"tree-form conversion also failed: {e}") from e


def _slice_flat(data, manifest: dict, like_keyed) -> dict:
    """Flat checkpoint -> tree run: slice saved buffers into leaf arrays.

    like_keyed: {key: leaf} of the target.  Saved flat buffers whose key
    is NOT a FlatState in the target are expanded under the slot keys
    the manifest recorded; the restore loop then matches the target's
    leaves by key, so renames/reorders fail loudly ("missing leaf")
    instead of shifting coordinates.
    """
    expanded = {}
    flat_meta = manifest.get("flat_state", {})
    for q, meta in flat_meta.items():
        if _is_flat(like_keyed.get(q)):
            continue
        for rel, arr in _expand_flat_buf(data[q], meta).items():
            k = q + SEP + rel
            leaf = like_keyed.get(k)
            if leaf is not None and tuple(
                    getattr(leaf, "shape", arr.shape)) != arr.shape:
                raise IOError(
                    f"flat-state slot for {k!r} has shape {arr.shape}, "
                    f"target leaf expects {getattr(leaf, 'shape', None)}")
            expanded[k] = arr
    return expanded


def restore(ckpt_dir: str | pathlib.Path, step: int,
            like: PyTree) -> PyTree:
    """Restore into the structure (and shardings) of ``like``.

    ``like`` may mix tree- and flat-state (``flatbuf.FlatState``) nodes
    freely with respect to how the checkpoint was saved: flat <-> tree
    conversion happens here, validated against the manifest's FlatLayout
    metadata.  A flat checkpoint whose slot table differs from the flat
    target (old copy-style manifest, different shard count) restores
    through the tree form when the logical leaves agree; a genuine
    structure mismatch raises naming the offending leaf and field.
    """
    path = pathlib.Path(ckpt_dir) / f"step_{step:010d}"
    if not _verify(path):
        raise IOError(f"checkpoint {path} failed integrity check")
    data = np.load(path / "arrays.npz")
    manifest = json.loads((path / "manifest.json").read_text())
    flat_meta = manifest.get("flat_state", {})
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        like, is_leaf=_is_flat)
    keyed = [(_key_of(p), leaf) for p, leaf in flat]
    expanded = _slice_flat(data, manifest, dict(keyed))

    def put(arr, leaf):
        if _is_prng_key(leaf):
            return jax.random.wrap_key_data(jnp.asarray(arr))
        if hasattr(leaf, "sharding") and hasattr(leaf, "dtype"):
            return jax.device_put(jnp.asarray(arr).astype(leaf.dtype),
                                  leaf.sharding)
        return arr

    leaves = []
    for key, leaf in keyed:
        if _is_flat(leaf):
            if key in flat_meta:              # flat -> flat
                mismatch = _slot_mismatch(flat_meta[key], leaf)
                if mismatch is None:
                    arr = data[key]
                    _check_batch(arr.shape, leaf, key)
                else:                         # different flat layout:
                    arr = _convert_flat(      # go through the tree form
                        data[key], flat_meta[key], key, leaf, mismatch)
            else:                             # tree ckpt -> flat run
                arr = _assemble_flat(data, key, leaf)
            leaves.append(leaf.replace(put(arr, leaf.buf)))
        elif key in data and key not in flat_meta:
            leaves.append(put(data[key], leaf))
        elif key in expanded:                 # flat ckpt -> tree run
            leaves.append(put(expanded[key], leaf))
        else:
            raise IOError(f"checkpoint is missing leaf {key!r}")
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_latest(ckpt_dir: str | pathlib.Path, like: PyTree
                   ) -> tuple[int, PyTree] | None:
    """Newest intact checkpoint (skipping corrupted ones), or None."""
    for step in reversed(available_steps(ckpt_dir)):
        path = pathlib.Path(ckpt_dir) / f"step_{step:010d}"
        if _verify(path):
            return step, restore(ckpt_dir, step, like)
    return None
