"""mistral-large-123b [dense]: 88L d12288 96H (kv=8) ff28672 v32768.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
"""
import dataclasses

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=28672,
    vocab=32768, head_dim=128, rope_theta=1e6,
    param_mode="fsdp", supports_long_context=False,
)

SMOKE = dataclasses.replace(
    CONFIG, name="mistral-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    param_mode="replicated",
)
