"""Elastic membership invariants (hypothesis property tests).

The deeper churn/chaos properties live in test_runtime_chaos.py; this
file keeps the fast array-level invariants of ``Membership.weights()``
on both granularities (legacy [P, D] device masks and client-granular
[P, D, K] with an active ClientConfig).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.clients import ClientConfig
from repro.runtime import elastic, failures


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 4), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_weights_invariants(pods, devs, seed):
    rng = np.random.default_rng(seed)
    m = elastic.Membership(pods, devs,
                           data_sizes=rng.integers(1, 100, (pods, devs)))
    # random failures, but keep at least one pod fully alive
    fail = rng.random((pods, devs)) < 0.4
    fail[rng.integers(pods)] = False
    for p, d in zip(*np.where(fail)):
        m.mark_failed(p, d)
    ew, dw, mask = m.weights()
    assert np.isclose(ew.sum(), 1.0)
    assert (ew >= 0).all() and (dw >= 0).all()
    # device weights renormalize within each live pod
    for q in range(pods):
        if ew[q] > 0:
            assert np.isclose(dw[q].sum(), 1.0)
    # masked devices carry no weight
    assert (dw[mask == 0] == 0).all()


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(2, 4),
       st.integers(0, 2**31 - 1))
def test_client_granular_weights_invariants(pods, devs, k, seed):
    """With an active ClientConfig the mask is per-voter [P, D, K],
    dev_weights stays the static physical-slice share (the |D_qk|
    shares ride inside the step), and edge weights track the LIVE
    client data."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 100, (pods, devs))
    m = elastic.Membership(pods, devs, clients=ClientConfig(count=k),
                           data_sizes=sizes)
    fail = rng.random((pods, devs, k)) < 0.3
    fail[rng.integers(pods)] = False
    for p, d, c in zip(*np.where(fail)):
        m.mark_failed(p, d, c)
    ew, dw, mask = m.weights()
    assert mask.shape == (pods, devs, k)
    assert np.isclose(ew.sum(), 1.0)
    want_dw = sizes / sizes.sum(axis=1, keepdims=True)
    np.testing.assert_allclose(dw, want_dw, rtol=1e-6)
    # a fully-live pod's edge weight is proportional to its data
    live_data = (m.client_sizes * mask).sum(axis=(1, 2))
    np.testing.assert_allclose(ew, live_data / live_data.sum(),
                               rtol=1e-6)


def test_pod_loss_renormalizes():
    m = elastic.Membership(2, 4)
    m.mark_failed(0)                      # whole pod down
    ew, dw, mask = m.weights()
    assert ew[0] == 0.0 and np.isclose(ew[1], 1.0)
    assert (mask[0] == 0).all()


def test_quorum_gates_pod():
    m = elastic.Membership(1, 4, quorum=0.75)
    m.mark_failed(0, 0)
    m.mark_failed(0, 1)                   # 50% live < 75% quorum
    assert not m.pod_live()[0]


def test_restore_and_fresh():
    m = elastic.Membership(2, 2, clients=ClientConfig(count=2),
                           quorum=0.25)
    m.mark_failed(0, 1, 0)
    m.mark_failed(1)
    m.restore(0, 1, 0, now=3.0)
    assert m.live[0, 1, 0] and m.last_seen[0, 1, 0] == 3.0
    assert not m.live[1].any()
    f = m.fresh()                         # all-live, same config
    assert f.live.all() and f.quorum == m.quorum
    assert f.clients is m.clients
    assert not m.live[1].any()            # fresh() copies, not mutates


def test_heartbeat_sweep():
    m = elastic.Membership(1, 2, heartbeat_timeout=1.0)
    m.heartbeat(0, 0, now=10.0)
    m.heartbeat(0, 1, now=5.0)
    m.sweep(now=10.5)
    assert m.live[0, 0] and not m.live[0, 1]


def test_failure_detector_straggler():
    det = failures.FailureDetector(failures.FailurePolicy(
        straggler_factor=2.0, patience=2))
    for _ in range(10):
        det.record_step(1.0)
    assert not det.device_slow(0, 0, 1.1)
    assert not det.device_slow(0, 1, 5.0)   # first offence
    assert det.device_slow(0, 1, 5.0)       # second -> demote
    # per-client keys escalate independently of the device-level key
    assert not det.device_slow(0, 1, 5.0, client=3)
    assert det.device_slow(0, 1, 5.0, client=3)


def test_failure_detector_loss():
    det = failures.FailureDetector()
    assert det.check_loss(1.0)
    assert not det.check_loss(float("nan"))
    assert not det.check_loss(float("inf"))


def test_membership_rejects_bad_sizes():
    with pytest.raises(ValueError):
        elastic.Membership(2, 2, data_sizes=np.ones((3, 2)))
