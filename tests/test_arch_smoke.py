"""Per-assigned-architecture smoke tests (reduced same-family configs).

For each of the 10 archs: instantiate the REDUCED config, run one forward
loss + one DC-HierSignSGD train step + a prefill/decode round-trip on CPU,
asserting output shapes and finiteness (assignment requirement f)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import hier
from repro.core.topology import single_device_topology
from repro.models import build

B_, T_ = 2, 32


def _batch(cfg):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (B_, T_), 0, cfg.vocab)}
    if cfg.family in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (B_, cfg.encoder_frames, cfg.frontend_dim))
    if cfg.n_patches:
        batch["patches"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(3), (B_, cfg.n_patches, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def topo():
    return single_device_topology()


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_smoke_forward_and_train_step(arch, topo):
    cfg = configs.get_smoke(arch)
    built = build.build_model(cfg, topo)
    params = built.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    loss = built.bundle.loss(params, batch, jax.random.PRNGKey(4))
    assert jnp.isfinite(loss), (arch, loss)

    algo = hier.AlgoConfig(method="dc_hier_signsgd", mu=1e-3, t_e=2,
                           rho=0.5, compute_dtype=jnp.float32)
    init_fn, step = hier.make_hier_step(topo, algo, built.bundle)
    state = init_fn(params, jax.random.PRNGKey(5))
    pd_batch = {"train": jax.tree.map(lambda a: a[None, None], batch)}
    ones = jnp.ones
    state, metrics = jax.jit(step)(state, pd_batch, ones((1,)),
                                   ones((1, 1)), ones((1, 1)))
    assert jnp.isfinite(metrics["loss"]), arch
    assert all(jnp.isfinite(x).all() for x in jax.tree.leaves(state.params)
               if jnp.issubdtype(x.dtype, jnp.floating)), arch
    # params actually moved (sign step of size mu on ~every coordinate)
    moved = sum(float(jnp.abs(a[0] - b).sum()) for a, b in zip(
        jax.tree.leaves(state.params), jax.tree.leaves(params)))
    assert moved > 0.0, arch


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_smoke_serve_roundtrip(arch, topo):
    cfg = configs.get_smoke(arch)
    built = build.build_model(cfg, topo)
    params = built.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    max_len = T_ + cfg.n_patches + 4
    logits, cache = built.prefill(params, batch, max_len=max_len)
    assert logits.shape == (B_, 1, cfg.vocab), (arch, logits.shape)
    assert jnp.isfinite(logits).all(), arch
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for _ in range(2):
        logits, cache = built.decode_step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    assert logits.shape == (B_, 1, cfg.vocab)
    assert jnp.isfinite(logits).all(), arch


def test_all_40_cells_enumerated():
    cells = list(configs.all_cells())
    assert len(cells) == 40
    skipped = [c for c in cells if not c[2]]
    # 6 documented skips: long_500k on the pure full-attention archs
    assert len(skipped) == 6, skipped
    assert all(c[1] == "long_500k" for c in skipped)


def test_prefill_decode_consistency():
    """Decoding token t after a prefill of length L must equal a prefill
    of length L+1 (cache correctness), incl. sliding-window layers."""
    cfg = configs.get_smoke("gemma3_1b")
    topo = single_device_topology()
    built = build.build_model(cfg, topo)
    params = built.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(9), (1, 16), 0, cfg.vocab)
    # prefill 15, decode the 16th
    lg15, cache = built.prefill(params, {"tokens": toks[:, :15]},
                                max_len=20)
    lg16_dec, _ = built.decode_step(params, cache, toks[:, 15:16])
    # direct prefill over all 16: last-position logits
    lg16_full, _ = built.prefill(params, {"tokens": toks}, max_len=20)
    import numpy as np
    np.testing.assert_allclose(np.asarray(lg16_dec[:, -1]),
                               np.asarray(lg16_full[:, -1]),
                               rtol=2e-2, atol=2e-2)
