"""arctic-480b [moe]: 35L d7168 56H (kv=8) ff4864 v32000, MoE 128e top-2
+ dense residual MLP in parallel (Snowflake Arctic dense-MoE hybrid).
[hf:Snowflake/snowflake-arctic-base; hf]
"""
import dataclasses

from repro.models.config import LMConfig, MoECfg

CONFIG = LMConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, head_dim=128, rope_theta=1e4,
    moe=MoECfg(n_experts=128, top_k=2, d_expert=4864,
               dense_residual_ff=4864, capacity_factor=1.25,
               group_tokens=1024),
    param_mode="fsdp", supports_long_context=False,
)

SMOKE = dataclasses.replace(
    CONFIG, name="arctic-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=96, vocab=256, head_dim=16,
    moe=MoECfg(n_experts=8, top_k=2, d_expert=96, dense_residual_ff=96,
               capacity_factor=1.5, group_tokens=32),
    param_mode="replicated",
)
