"""Transport benchmark: the sign->pack->vote->update sweep per transport.

Times one full local-step direction+update (DC correction fused pre-sign,
majority vote over the ``data`` axis, ``v <- v - mu*vote``) for each sign
transport (``ag_packed`` per-leaf, ``ar_int8``, flat-buffer ``fused``)
across model sizes and logical (pods x devices) counts, and extracts the
static HBM / collective byte accounting from the optimized HLO via
``benchmarks.hlo_analysis`` -- the same analyzer the dry-run rooflines use.

Runs anywhere (CPU uses the pure-jnp fallback path, which is what GSPMD
lowers on real meshes); on TPU the fused transport's local sweeps run the
Pallas kernels.  Emits machine-readable ``BENCH_transports.json`` (checked
in to seed the perf trajectory) plus a CSV mirror on stdout.

  PYTHONPATH=src python benchmarks/bench_transports.py \
      --sizes 1000000,8000000 --devices 1x8,2x4 --iters 3
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks import hlo_analysis
from repro.core import flatbuf, signs, votes
from repro.core.topology import single_device_topology

MU, RHO = 1e-3, 0.2

TRANSPORTS = ("ag_packed", "ar_int8", "fused", "fused_flat")


def model_shapes(n_target: int) -> list[tuple[int, ...]]:
    """Mixed leaf shapes ~ a transformer stack: wide aligned matrices plus
    odd-minor vectors (norm scales / biases) that defeat 32-bit packing."""
    shapes: list[tuple[int, ...]] = [(33,), (129,), (513,), (1023,)]
    remaining = n_target - sum(s[0] for s in shapes)
    d = 1024
    while remaining > 0:
        r = min(max(remaining // d, 1), 4096)
        shapes.append((r, d))
        remaining -= r * d
    return shapes


def make_inputs(n_target: int, pods: int, devs: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    g_dev, delta, params = {}, {}, {}
    for i, s in enumerate(model_shapes(n_target)):
        k = jax.random.fold_in(key, i)
        g_dev[f"leaf{i}"] = jax.random.normal(k, (pods, devs) + s)
        delta[f"leaf{i}"] = jax.random.normal(
            jax.random.fold_in(k, 1), (pods,) + s)
        params[f"leaf{i}"] = jax.random.normal(
            jax.random.fold_in(k, 2), (pods,) + s)
    return g_dev, delta, params


def make_step(topo, transport: str, layout=None):
    """One DC local step: direction via ``transport`` + sign-descent update.

    Mirrors ``core.hier.local_direction`` exactly (per-leaf delta
    broadcast + add for the per-leaf transports; correction folded into
    the flat sweep for ``fused``).  ``fused_flat`` is the
    ``state_layout="flat"`` hot path: params/delta are already flat
    buffers and the update is ONE whole-model ``vote_update``
    read-modify-write (``votes.fused_sign_vote_update``)."""

    if transport == "fused_flat":
        def step_flat(g_dev, delta_buf, params_buf):
            return votes.fused_sign_vote_update(
                topo, layout, g_dev, delta_buf, RHO, None, params_buf,
                jnp.float32(MU), mu_static=MU)

        return step_flat

    def step(g_dev, delta, params):
        if transport == "fused":
            direction = votes.fused_sign_vote(topo, g_dev, delta, RHO, None)
        else:
            u = jax.tree.map(
                lambda g, dl: g + RHO * dl[:, None].astype(g.dtype),
                g_dev, delta)
            s = jax.tree.map(signs.sgn, u)
            direction = jax.tree.map(
                lambda s_: votes.majority_vote_dev(
                    topo, s_, None, transport,
                    P(*([None] * (s_.ndim - 2)))),
                s)
        return jax.tree.map(
            lambda v, d: v - MU * d.astype(v.dtype), params, direction)

    return step


def bench_one(topo, transport, n_target, pods, devs, iters):
    g_dev, delta, params = make_inputs(n_target, pods, devs)
    n_real = sum(int(x[0, 0].size) for x in jax.tree.leaves(g_dev))
    layout = None
    if transport == "fused_flat":
        layout = flatbuf.make_layout(g_dev, batch_dims=2)
        delta = flatbuf.flatten_tree(layout, delta, batch_dims=1,
                                     dtype=jnp.float32)
        params = flatbuf.flatten_tree(layout, params, batch_dims=1,
                                      dtype=jnp.float32)
    step = jax.jit(make_step(topo, transport, layout))
    lowered = step.lower(g_dev, delta, params)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    stats = hlo_analysis.analyze_hlo_text(hlo)

    out = jax.block_until_ready(step(g_dev, delta, params))   # warmup
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(step(g_dev, delta, params))
    dt = (time.perf_counter() - t0) / iters
    del out
    return {
        "transport": transport,
        "n_params": n_real,
        "pods": pods,
        "devices_per_pod": devs,
        "us_per_step": dt * 1e6,
        "hbm_bytes": stats["hbm_bytes"],
        "hbm_bytes_out": stats["hbm_bytes_out"],
        "collective_bytes": stats.get("collective_bytes_total", 0.0),
        "wire_bits_per_coord_uplink": signs.uplink_bits(
            "dc_hier_signsgd", n_real, 1) / n_real,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="1000000,8000000",
                    help="comma-separated param counts (paper range 1M-100M)")
    ap.add_argument("--devices", default="1x8,2x4",
                    help="comma-separated PxD logical device counts")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=str(
        pathlib.Path(__file__).resolve().parents[1]
        / "BENCH_transports.json"))
    args = ap.parse_args()

    topo = single_device_topology()
    sizes = [int(float(s)) for s in args.sizes.split(",")]
    devices = [tuple(int(x) for x in d.split("x"))
               for d in args.devices.split(",")]

    rows, checks = [], []
    print("transport,n_params,pods,devices,us_per_step,hbm_bytes,"
          "hbm_bytes_out")
    for n in sizes:
        for pods, devs in devices:
            cell = {}
            for transport in TRANSPORTS:
                r = bench_one(topo, transport, n, pods, devs, args.iters)
                rows.append(r)
                cell[transport] = r
                print(f"{r['transport']},{r['n_params']},{r['pods']},"
                      f"{r['devices_per_pod']},{r['us_per_step']:.1f},"
                      f"{r['hbm_bytes']:.0f},{r['hbm_bytes_out']:.0f}")
            # acceptance: fused <= per-leaf ag_packed in HBM bytes per
            # step, and the flat-state path no worse than fused
            checks.append({
                "n_params": cell["fused"]["n_params"],
                "pods": pods, "devices_per_pod": devs,
                "fused_hbm_bytes": cell["fused"]["hbm_bytes"],
                "fused_flat_hbm_bytes": cell["fused_flat"]["hbm_bytes"],
                "ag_packed_hbm_bytes": cell["ag_packed"]["hbm_bytes"],
                "fused_le_ag_packed": (cell["fused"]["hbm_bytes"]
                                       <= cell["ag_packed"]["hbm_bytes"]),
                "fused_flat_le_ag_packed": (
                    cell["fused_flat"]["hbm_bytes"]
                    <= cell["ag_packed"]["hbm_bytes"]),
            })
    report = {
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "mu": MU, "rho": RHO, "iters": args.iters,
            "note": "DC local step: sign(g+rho*delta) -> vote -> update; "
                    "single physical device, logical [P, D] dims; "
                    "hbm/collective bytes from hlo_analysis on the "
                    "optimized HLO.",
        },
        "rows": rows,
        "hbm_check": checks,
        "all_fused_le_ag_packed": all(c["fused_le_ag_packed"]
                                      for c in checks),
        "all_fused_flat_le_ag_packed": all(c["fused_flat_le_ag_packed"]
                                           for c in checks),
    }
    out_path = pathlib.Path(args.out)
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out_path} "
          f"(all_fused_le_ag_packed={report['all_fused_le_ag_packed']})")


if __name__ == "__main__":
    main()
