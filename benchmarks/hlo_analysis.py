"""Static cost analysis of optimized HLO text (roofline extraction).

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits each
while-loop body ONCE, so any scanned program (layer scans, T_E rounds,
q-chunked attention) is under-reported by its trip count.  This analyzer
parses the optimized HLO text, recovers scan trip counts from loop
conditions, and accumulates:

  * flops            -- 2*M*N*K for dot ops (+ ~1 flop/elem for fused
                        elementwise arithmetic), x trip multipliers;
  * hbm_bytes        -- sum of operand+output bytes of every top-level
                        (post-fusion) instruction: XLA's own HBM-traffic
                        model for fusions counts exactly these;
  * collective bytes -- operand bytes of all-reduce / all-gather /
                        reduce-scatter / all-to-all / collective-permute
                        (+ their async -start forms), each attributed to
                        the mesh axes its replica groups span.

Used by launch/dryrun.py (Sec. Dry-run) and benchmarks/roofline.py.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?([%\w.\-]+)\s*=\s*(.*)$")

ELEMENTWISE_HINT = re.compile(
    r"^(add|subtract|multiply|divide|exponential|log|tanh|maximum|minimum|"
    r"power|rsqrt|sqrt|negate|abs|select|compare|and|or|xor|convert|"
    r"logistic|sign|floor|ceil|cosine|sine|reduce|clamp|remainder)")


def _type_bytes_elems(type_str: str) -> tuple[int, int]:
    """Total (bytes, elements) across all shapes in a type string."""
    total_b = total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            elems = math.prod(int(d) for d in dims.split(","))
        total_e += elems
        total_b += elems * DTYPE_BYTES[dt]
    return total_b, total_e


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


class Instr:
    __slots__ = ("name", "type_str", "opcode", "operands", "attrs", "root")

    def __init__(self, name, type_str, opcode, operands, attrs, root):
        self.name = name
        self.type_str = type_str
        self.opcode = opcode
        self.operands = operands
        self.attrs = attrs
        self.root = root


def _split_type_rest(rhs: str):
    """rhs = '<type> opcode(...), attrs' where tuple types are (...)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                return rhs[: i + 1], rhs[i + 1:].strip()
    i = rhs.find(" ")
    return rhs[:i], rhs[i + 1:].strip()


def _parse_call(rest: str):
    """'opcode(operands), attrs' -> (opcode, [operand names], attrs)."""
    i = rest.find("(")
    opcode = rest[:i].strip()
    depth = 0
    for j in range(i, len(rest)):
        depth += rest[j] == "("
        depth -= rest[j] == ")"
        if depth == 0:
            break
    inner = rest[i + 1: j]
    attrs = rest[j + 1:].lstrip(", ")
    ops = []
    depth = 0
    cur = ""
    for ch in inner:
        if ch == "," and depth == 0:
            ops.append(cur.strip())
            cur = ""
        else:
            depth += ch in "([{"
            depth -= ch in ")]}"
            cur += ch
    if cur.strip():
        ops.append(cur.strip())
    names = []
    for o in ops:
        o = o.strip()
        # operands appear either as bare refs ("%fusion.5" / "fusion.5") or
        # fully typed ("f32[8,1024]{1,0} %p.19"): prefer the trailing %name
        m = (re.search(r"%([\w.\-]+)\s*$", o)
             or re.match(r"%?([\w.\-]+)", o))
        names.append(m.group(1) if m else o)
    return opcode, names, attrs


def parse_module(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur = None
    for line in text.splitlines():
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        if s.endswith("{") and "->" in s:
            header = s[:-1].strip()
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", header)
            if m and "(" in header:
                cur = m.group(1)
                comps[cur] = []
                if s.startswith("ENTRY"):
                    comps["__entry__"] = comps[cur]
            continue
        if s == "}" or s.startswith("}"):
            continue
        if cur is None or "=" not in s:
            continue
        m = _INSTR_RE.match(s)
        if not m:
            continue
        root, name, rhs = m.group(1), m.group(2).lstrip("%"), m.group(3)
        if "(" not in rhs:
            continue
        try:
            type_str, rest = _split_type_rest(rhs)
            opcode, operands, attrs = _parse_call(rest)
        except Exception:
            continue
        comps[cur].append(Instr(name, type_str, opcode, operands, attrs,
                                bool(root)))
    return comps


def _comp_ref(attrs: str, key: str):
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _find(instrs, name):
    for i in instrs:
        if i.name == name:
            return i
    return None


def _replica_group_axes(attrs: str, axis_sizes: dict[str, int] | None):
    """Label which mesh axes a collective's replica groups span.

    Collectives whose groups cannot be attributed (no ``axis_sizes``
    passed, or an unparsed replica_groups format) are labeled
    ``"unattributed"`` -- they land in their own per-axis bucket and are
    counted exactly once, never smeared across every axis filter.
    """
    if not axis_sizes:
        return "unattributed", 0
    sizes = list(axis_sizes.values())
    names = list(axis_sizes.keys())
    n_dev = math.prod(sizes)
    group = None
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", attrs)
    if m:
        group = [int(x) for x in m.group(1).split(",")]
    else:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
                      r"(?:T\(([0-9,]+)\))?", attrs)
        if m:
            g, s = int(m.group(1)), int(m.group(2))
            dims = [int(x) for x in m.group(3).split(",")]
            arr = np.arange(math.prod(dims)).reshape(dims)
            if m.group(4):
                arr = arr.transpose([int(x) for x in m.group(4).split(",")])
            arr = arr.reshape(g, s)
            group = list(arr[0])
    if not group:
        return "unattributed", 0
    coords = np.array(np.unravel_index(np.array(group), sizes)).T
    varying = [names[i] for i in range(len(sizes))
               if len(set(coords[:, i])) > 1]
    return "+".join(varying) if varying else "self", len(group)


def analyze_hlo_text(text: str, axis_sizes: dict[str, int] | None = None):
    comps = parse_module(text)
    entry = comps.get("__entry__")
    if entry is None:
        return {"error": "no entry computation found"}

    # keep raw lines for constant extraction
    const_vals: dict[str, int] = {}
    for m in re.finditer(r"%?([\w.\-]+) = s32\[\] constant\((\d+)\)", text):
        const_vals[m.group(1)] = int(m.group(2))

    def trip_of(cond_name):
        for ins in comps.get(cond_name, []):
            if ins.opcode == "compare" and "direction=LT" in ins.attrs:
                for op in ins.operands:
                    if op in const_vals:
                        return const_vals[op]
        vals = [const_vals[i.name] for i in comps.get(cond_name, [])
                if i.name in const_vals]
        return max(vals) if vals else 1

    totals = {
        "flops": 0.0, "hbm_bytes": 0.0, "hbm_bytes_out": 0.0,
        "wire_bytes": 0.0,
        "collectives": defaultdict(lambda: {"bytes": 0.0, "count": 0}),
        "per_axis_bytes": defaultdict(float),
        "per_axis_op_bytes": defaultdict(float),
        "while_trips": {},
        "top_collectives": [],
    }
    visited_fusion_flops: dict[str, float] = {}

    def fusion_flops(comp_name: str) -> float:
        if comp_name in visited_fusion_flops:
            return visited_fusion_flops[comp_name]
        fl = 0.0
        for ins in comps.get(comp_name, []):
            fl += instr_flops(ins, comp_name)
        visited_fusion_flops[comp_name] = fl
        return fl

    def instr_flops(ins: Instr, comp_name: str) -> float:
        if ins.opcode == "dot":
            out_b, out_e = _type_bytes_elems(ins.type_str)
            lhs = _find(comps[comp_name], ins.operands[0])
            k = 1.0
            if lhs is not None:
                dims = _shape_dims(lhs.type_str)
                m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                              ins.attrs)
                if m and m.group(1):
                    for d in m.group(1).split(","):
                        if int(d) < len(dims):
                            k *= dims[int(d)]
            return 2.0 * out_e * k
        if ins.opcode == "fusion":
            callee = _comp_ref(ins.attrs, "calls")
            return fusion_flops(callee) if callee else 0.0
        if ins.opcode in ("custom-call",):
            if "matmul" in ins.attrs or "dot" in ins.attrs.lower():
                _, out_e = _type_bytes_elems(ins.type_str)
                return 2.0 * out_e * 128.0     # conservative fallback
            return 0.0
        if ELEMENTWISE_HINT.match(ins.opcode):
            _, out_e = _type_bytes_elems(ins.type_str)
            return float(out_e)
        return 0.0

    SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "after-all", "iota"}

    def walk(comp_name: str, mult: float):
        for ins in comps.get(comp_name, []):
            op = ins.opcode
            if op == "while":
                body = _comp_ref(ins.attrs, "body")
                cond = _comp_ref(ins.attrs, "condition")
                trips = trip_of(cond) if cond else 1
                totals["while_trips"][body] = trips
                walk(body, mult * trips)
                continue
            if op == "conditional":
                for key in ("true_computation", "false_computation"):
                    ref = _comp_ref(ins.attrs, key)
                    if ref:
                        walk(ref, mult)
                m = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
                if m:
                    for ref in m.group(1).split(","):
                        walk(ref.strip().lstrip("%"), mult)
                continue
            if op == "call":
                ref = _comp_ref(ins.attrs, "to_apply")
                if ref:
                    walk(ref, mult)
                continue
            # flops + bytes for regular instructions
            totals["flops"] += mult * instr_flops(ins, comp_name)
            if op not in SKIP_BYTES:
                out_b, _ = _type_bytes_elems(ins.type_str)
                if op in ("dynamic-slice", "slice", "gather"):
                    # reads only the sliced region (~= output), NOT the
                    # full operand: critical inside scans, where charging
                    # the stacked xs per iteration overstates traffic by
                    # the trip count.
                    in_b = out_b
                elif op == "dynamic-update-slice":
                    # read-modify-write of the update region only
                    upd = (_find(comps[comp_name], ins.operands[1])
                           if len(ins.operands) > 1 else None)
                    ub = (_type_bytes_elems(upd.type_str)[0]
                          if upd is not None else 0)
                    totals["hbm_bytes"] += mult * 2 * ub
                    totals["hbm_bytes_out"] += mult * ub
                    continue
                else:
                    sliced_fusion = False
                    if op == "fusion":
                        callee = comps.get(_comp_ref(ins.attrs, "calls"),
                                           [])
                        sliced_fusion = any(
                            i.opcode in ("dynamic-slice", "slice",
                                         "gather", "scatter",
                                         "dynamic-update-slice")
                            for i in callee)
                    in_b = 0
                    for o in ins.operands:
                        src = _find(comps[comp_name], o)
                        if src is not None and src.opcode not in (
                                "constant", "tuple"):
                            b, _ = _type_bytes_elems(src.type_str)
                            if sliced_fusion and (b == out_b
                                                  or b >= 32 * max(out_b,
                                                                   1)):
                                # aliased scan accumulator / sliced source:
                                # the fusion touches a slice, not the full
                                # stacked buffer (in-place DUS / DS read)
                                continue
                            in_b += b
                    if sliced_fusion and in_b == 0:
                        in_b = out_b  # at least the slice region
                # upper bound: every op re-reads its operands (CPU fusion
                # granularity); lower bound: each tensor written once
                # (perfect-fusion limit).  TPU truth lies between.
                totals["hbm_bytes"] += mult * (out_b + in_b)
                totals["hbm_bytes_out"] += mult * out_b
            base = op.replace("-start", "")
            if base in COLLECTIVES and not op.endswith("-done"):
                in_b = 0
                for o in ins.operands:
                    src = _find(comps[comp_name], o)
                    if src is not None:
                        b, _ = _type_bytes_elems(src.type_str)
                        in_b += b
                axes, gsz = _replica_group_axes(ins.attrs, axis_sizes)
                # ring wire cost: AR moves ~2N(K-1)/K, AG/RS/A2A ~N(K-1)/K
                k = max(gsz, 2)
                ring = (k - 1) / k
                factor = 2.0 * ring if base == "all-reduce" else ring
                totals["wire_bytes"] += mult * in_b * factor
                totals["collectives"][base]["bytes"] += mult * in_b
                totals["collectives"][base]["count"] += mult
                totals["per_axis_bytes"][axes] += mult * in_b
                totals["per_axis_op_bytes"][f"{base}@{axes}"] += mult * in_b
                totals["top_collectives"].append(
                    {"op": base, "bytes": in_b, "mult": mult,
                     "axes": axes, "group_size": gsz,
                     "comp": comp_name})

    walk("__entry__", 1.0)
    totals["collectives"] = {k: v for k, v in totals["collectives"].items()}
    totals["per_axis_bytes"] = dict(totals["per_axis_bytes"])
    totals["per_axis_op_bytes"] = dict(totals["per_axis_op_bytes"])
    totals["collective_bytes_total"] = sum(
        v["bytes"] for v in totals["collectives"].values())
    totals["top_collectives"] = sorted(
        totals["top_collectives"], key=lambda d: -d["bytes"] * d["mult"]
    )[:24]
    return totals


def collective_bytes(stats: dict, op: str | None = None,
                     axis: str | None = None) -> float:
    """Total operand bytes of collectives filtered by op and/or mesh axis.

    ``axis`` matches any replica-group label that *includes* the axis
    (``per_axis_op_bytes`` labels multi-axis groups ``"a+b"``).
    Collectives whose replica groups could NOT be attributed (no
    ``axis_sizes`` passed, or an unparsed replica_groups format) are
    accounted ONCE under the explicit ``"unattributed"`` label -- query
    them with ``axis="unattributed"``.  They no longer count toward
    every named-axis filter (which double-counted one unattributed
    gather into both the data- and model-axis totals); an acceptance
    check that needs strictness must also assert the unattributed
    bucket is empty -- see :func:`assert_axis_free`.
    """
    total = 0.0
    for key, b in stats.get("per_axis_op_bytes", {}).items():
        k_op, k_axes = key.split("@", 1)
        if op is not None and k_op != op:
            continue
        if axis is not None and axis not in k_axes.split("+"):
            continue
        total += b
    return total


def assert_axis_free(stats: dict, op: str, axis: str):
    """Strict zero-bytes assertion for ``op`` on ``axis``.

    Fails if the op moved any attributed bytes on the axis OR if any
    bytes of the op are unattributed (which could hide axis traffic) --
    the check can never pass vacuously on a module the analyzer failed
    to attribute.
    """
    attributed = collective_bytes(stats, op=op, axis=axis)
    unattributed = collective_bytes(stats, op=op, axis="unattributed")
    assert attributed == 0, (
        f"{attributed:.0f} {op} bytes over the {axis!r} axis "
        f"({stats.get('per_axis_op_bytes')})")
    assert unattributed == 0, (
        f"{unattributed:.0f} {op} bytes could not be attributed to a "
        f"mesh axis -- the {axis!r}-axis check would be vacuous "
        f"({stats.get('per_axis_op_bytes')})")
