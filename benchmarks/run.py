"""Benchmark entry point: one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV and mirrors it to
reports/bench_results.csv plus machine-readable
reports/bench_results.json (so future PRs can diff perf).
(The transport sweep lives in benchmarks/bench_transports.py and emits
BENCH_transports.json.)

  table2    device->edge uplink bits per round  (paper Table II)
  fig2      4-method accuracy, IID & non-IID    (paper Fig. 2)
  fig3      T_E sweep, DC vs plain              (paper Fig. 3)
  fig4      rho sensitivity at T_E=15           (paper Fig. 4)
  clients   virtual-client scale-out (K=64, p=0.1): participating
            uplink + round cost (always cost-model priced)
  methods   drift-correction method axis: Thm-style loss proxy +
            per-client downlink (dc / scaffold / mtgc accounting)
  overlap   cloud sync schedule: per-round wall-clock sync vs overlap
            as a function of the cloud RTT (always cost-model priced)
  roofline  3-term roofline per dry-run cell    (deliverable g)

Flags: ``--only fig2`` to run a subset; ``--fast`` is the CI profile --
fig2/3/4 are priced by the dry-run cost model (benchmarks/cost_model.py,
Thm 1/2 constants + analytic round cost) instead of real CPU training,
so the whole sweep completes in seconds while emitting the same row
names and JSON schema (cost-model rows are tagged ``src=cost_model``).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    choices=["all", "table2", "fig2", "fig3", "fig4",
                             "clients", "methods", "overlap",
                             "roofline"])
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out-dir", default=None,
                    help="directory for bench_results.{csv,json} "
                         "(default: <repo>/reports)")
    args = ap.parse_args()

    root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(root))
    sys.path.insert(0, str(root / "src"))
    from benchmarks import cost_model, paper_figs, roofline

    rows = []
    want = lambda k: args.only in ("all", k)
    if want("table2"):
        rows += paper_figs.table2_uplink_cost()
    if want("fig2"):
        rows += (cost_model.fig2_rows(paper_figs.METHODS) if args.fast
                 else paper_figs.fig2_accuracy(seeds=(0, 1)))
    if want("fig3"):
        rows += (cost_model.fig3_rows(te_values=(5, 15)) if args.fast
                 else paper_figs.fig3_te_sweep(te_values=(5, 15, 30)))
    if want("fig4"):
        rows += (cost_model.fig4_rows(rhos=(0.0, 0.2, 1.0)) if args.fast
                 else paper_figs.fig4_rho_sweep(
                     rhos=(0.0, 0.1, 0.2, 0.5, 1.0)))
    if want("clients"):
        # virtual-client scale-out (always cost-model priced: the row
        # exists to track the participating-uplink accounting)
        rows += cost_model.clients_rows(cells=((64, 0.1),))
    if want("methods"):
        # drift-correction method axis (always cost-model priced): the
        # Thm-style stationarity proxy next to each correction's
        # per-client downlink bytes (dc anchor vs scaffold c_global vs
        # mtgc two-term)
        rows += cost_model.methods_rows()
    if want("overlap"):
        # cloud sync schedule (always cost-model priced): what hiding
        # the cloud RTT behind a round of local stepping buys per round
        rows += cost_model.overlap_rows()
    if want("roofline"):
        try:
            rows += roofline.roofline_rows()
        except Exception as e:
            rows.append(("roofline/ERROR", 0.0, str(e)[:80]))

    out = ["name,us_per_call,derived"]
    for name, us, derived in rows:
        out.append(f"{name},{us:.1f},{derived}")
    csv = "\n".join(out)
    print(csv)
    rep = (pathlib.Path(args.out_dir) if args.out_dir
           else pathlib.Path(__file__).resolve().parents[1] / "reports")
    rep.mkdir(parents=True, exist_ok=True)
    (rep / "bench_results.csv").write_text(csv + "\n")
    (rep / "bench_results.json").write_text(json.dumps({
        "rows": [{"name": name, "us_per_call": us, "derived": derived}
                 for name, us, derived in rows],
    }, indent=2) + "\n")


if __name__ == "__main__":
    main()
