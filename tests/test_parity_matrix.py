"""The transport x method x state_layout x regime parity matrix.

One shared toy trajectory (tests/helpers/parity_harness.py) is run
through every supported train-step combination:

  * methods: hier_signsgd | dc_hier_signsgd | scaffold_hier_signsgd |
    mtgc_hier_signsgd | hier_sgd | hier_local_qsgd
  * transports: ag_packed | ar_int8 | fused          (sign methods)
  * state layouts: tree | flat
  * regimes: replicated | fsdp  (flat is replicated-only by design)
  * virtual clients: K in {1, 4} x participation in {full, sampled(0.5),
    weighted |D_qk|}  (replicated-only; K=1/full/unit-weight must be
    BITWISE the legacy trajectory -- the migration safety net)

Sign transports and state layouts must agree BITWISE (ties -> +1 by
construction, update arithmetic per-coordinate identical); the paper
oracle (``ref_fed``) and the FSDP regime agree within float tolerance.
The multi-device version of the same matrix (2x2x2 mesh, straggler
masks, EF/momentum) runs in a subprocess -- see
helpers/parity_matrix_check.py -- and is marked ``slow``; there the
flat cells exercise the model-axis-SHARDED layout + shard_map fused
program, and helpers/sharded_fused_check.py is the dedicated
multi-chip fused acceptance cell (bitwise parity on both routes plus
the no-model-axis-gather HLO assert).
"""
import dataclasses
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).parent / "helpers"))
import parity_harness as H  # noqa: E402

from repro.core import flatbuf, hier  # noqa: E402
from repro.core.topology import single_device_topology  # noqa: E402
from repro.kernels import vote_update as _vu  # noqa: E402

HELPERS = pathlib.Path(__file__).parent / "helpers"
SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


@pytest.fixture(scope="module")
def topo():
    return single_device_topology()


@pytest.fixture(scope="module")
def problem():
    return H.make_problem(pods=1, devs=1)


@pytest.fixture(scope="module")
def refs():
    """Lazily-computed (ag_packed, tree, replicated) reference per
    method -- the shared fixture every matrix cell compares against."""
    return {}


def _ref(refs, topo, problem, method):
    if method not in refs:
        refs[method] = H.run_hier(topo, problem, method)
    return refs[method]


@pytest.mark.parametrize("method,transport,layout", H.matrix_cells())
def test_matrix_cross_parity(topo, problem, refs, method, transport,
                             layout):
    """Every cell is bitwise identical to the reference cell."""
    ref, _ = _ref(refs, topo, problem, method)
    got, _ = H.run_hier(topo, problem, method, transport, layout)
    H.assert_trees_equal(ref, got, f"{method}/{transport}/{layout}")


@pytest.mark.parametrize("method", ["hier_signsgd", "dc_hier_signsgd",
                                    "scaffold_hier_signsgd",
                                    "mtgc_hier_signsgd", "hier_sgd"])
def test_matrix_vs_oracle(topo, problem, refs, method):
    """Cloud-aggregated final model == the ref_fed paper oracle.

    (hier_local_qsgd is excluded: its stochastic quantizer draws from a
    different rng stream in the oracle, so trajectories diverge by
    design.)"""
    params, ew = _ref(refs, topo, problem, method)
    oracle = H.run_oracle(problem, method)
    H.assert_trees_equal(H.aggregate(params, ew), oracle,
                         f"oracle/{method}", exact=False, atol=1e-5)


@pytest.mark.parametrize("layout", H.LAYOUTS)
@pytest.mark.parametrize("kw", [{"error_feedback": True},
                                {"momentum": 0.9}, {"decay": True}],
                         ids=["ef", "momentum", "decay"])
def test_matrix_options(topo, problem, layout, kw):
    """Beyond-paper options stay layout- and transport-invariant
    (decay also exercises the dynamic-mu fused update route)."""
    ref, _ = H.run_hier(topo, problem, "dc_hier_signsgd", "ag_packed",
                        "tree", **kw)
    got, _ = H.run_hier(topo, problem, "dc_hier_signsgd", "fused",
                        layout, **kw)
    H.assert_trees_equal(ref, got, f"options/{kw}/{layout}")


@pytest.mark.parametrize("method", ["hier_signsgd", "dc_hier_signsgd",
                                    "hier_sgd"])
def test_matrix_fsdp_regime(topo, problem, refs, method):
    ref, _ = _ref(refs, topo, problem, method)
    got, _ = H.run_hier(topo, problem, method, regime="fsdp")
    H.assert_trees_equal(ref, got, f"fsdp/{method}", exact=False,
                         atol=1e-6)


def test_flat_rejects_fsdp(topo):
    bundle = H.make_bundle("fsdp")
    with pytest.raises(ValueError, match="replicated"):
        hier.make_hier_step(topo, hier.AlgoConfig(state_layout="flat"),
                            bundle)
    with pytest.raises(ValueError):
        hier.AlgoConfig(state_layout="bogus")


def test_unknown_method_error_lists_all_methods():
    """Bugfix regression: the unknown-method ValueError names every
    supported method so the caller can correct a typo from the message
    alone."""
    with pytest.raises(ValueError) as exc:
        hier.AlgoConfig(method="hier_signsg")
    for method in hier.ALL_METHODS:
        assert method in str(exc.value)
    with pytest.raises(ValueError, match="cloud_period"):
        hier.AlgoConfig(method="mtgc_hier_signsgd", cloud_period=0)


@pytest.mark.parametrize("method", hier.CLIENT_CORRECTION_METHODS)
def test_correction_methods_reject_fsdp(topo, method):
    """scaffold/mtgc per-client state rides the explicit voter axis,
    which the FSDP lift never materializes."""
    bundle = H.make_bundle("fsdp")
    with pytest.raises(ValueError, match="replicated"):
        hier.make_hier_step(topo, hier.AlgoConfig(method=method), bundle)


# ---------------------------------------------------------------------------
# Virtual-client axis: K clients per data slice x participation regime
# ---------------------------------------------------------------------------

CLIENT_CELLS = [(1, "full"), (1, "sampled"), (4, "full"), (4, "sampled"),
                (4, "fixed"), (4, "weighted"), (4, "sampled_weighted")]


def test_client_k1_equivalence(topo, problem, refs):
    """HEADLINE migration check: K=1 / full participation / unit
    weights through the ACTIVE virtual-client machinery (carving,
    participation mask, weighted popcount, participating shares) is
    bitwise identical to the legacy cell on every transport x layout.
    (The inactive default ClientConfig compiles the legacy step
    verbatim, which the unchanged matrix above already covers.)"""
    cc = H.client_cfg(1, 1, 1, "full")
    assert cc.active          # unit weights force the virtual path
    for method in ("hier_signsgd", "dc_hier_signsgd", "hier_sgd"):
        ref, _ = _ref(refs, topo, problem, method)
        transports = (H.SIGN_TRANSPORTS
                      if method != "hier_sgd" else ("ag_packed",))
        for transport in transports:
            for layout in H.LAYOUTS:
                got, _ = H.run_hier(topo, problem, method, transport,
                                    layout, clients=cc)
                H.assert_trees_equal(
                    ref, got, f"k1-equiv/{method}/{transport}/{layout}")


@pytest.mark.parametrize("k,regime", CLIENT_CELLS)
def test_client_matrix_vs_oracle(topo, problem, k, regime):
    """Every (K, participation) cell matches the extended ref_fed
    oracle (same pinned per-round masks, |D_qk| vote weights and
    participating shares), and the (fused, flat) cell is bitwise
    identical to the (ag_packed, tree) cell."""
    cc = H.client_cfg(1, 1, k, regime)
    ref, ew = H.run_hier(topo, problem, "dc_hier_signsgd", clients=cc)
    got, _ = H.run_hier(topo, problem, "dc_hier_signsgd", "fused", "flat",
                        clients=cc)
    H.assert_trees_equal(ref, got, f"clients/K{k}/{regime}/fused-flat")
    oracle = H.run_oracle(problem, "dc_hier_signsgd", clients=cc)
    H.assert_trees_equal(H.aggregate(ref, ew), oracle,
                         f"clients-oracle/K{k}/{regime}", exact=False,
                         atol=1e-5)


def test_client_sampled_weighted_cross_transport(topo, problem):
    """The hardest cell -- K=4, Bernoulli(0.5) participation, unequal
    |D_qk| -- is bitwise identical across ALL transports and state
    layouts (identical pinned masks and weighted tallies everywhere)."""
    cc = H.client_cfg(1, 1, 4, "sampled_weighted")
    ref = None
    for transport in H.SIGN_TRANSPORTS:
        for layout in H.LAYOUTS:
            got, _ = H.run_hier(topo, problem, "dc_hier_signsgd",
                                transport, layout, clients=cc)
            ref = got if ref is None else ref
            H.assert_trees_equal(
                ref, got, f"clients-x/{transport}/{layout}")


@pytest.mark.parametrize("method", hier.CLIENT_CORRECTION_METHODS)
@pytest.mark.parametrize("regime", ["full", "sampled", "weighted"])
def test_correction_client_cells(topo, problem, method, regime):
    """Drift-correction method axis under virtual clients: every
    transport x layout cell of {scaffold, mtgc} is bitwise identical
    under K=4 x {full, sampled(0.5), weighted |D_qk|} participation,
    the streamed in-step loop lands on the same state, and the
    cloud-aggregated model matches the grown ref_fed oracle (fresh
    control variates, EF-style carry-forward for abstainers)."""
    cc = H.client_cfg(1, 1, 4, regime)
    ref = ew = None
    for transport in H.SIGN_TRANSPORTS:
        for layout in H.LAYOUTS:
            got, w = H.run_hier(topo, problem, method, transport, layout,
                                clients=cc)
            if ref is None:
                ref, ew = got, w
            H.assert_trees_equal(
                ref, got, f"corr/{method}/{regime}/{transport}/{layout}")
    got, _ = H.run_hier(topo, problem, method, "fused", "flat",
                        clients=_stream(cc))
    H.assert_trees_equal(ref, got, f"corr-stream/{method}/{regime}")
    oracle = H.run_oracle(problem, method, clients=cc)
    H.assert_trees_equal(H.aggregate(ref, ew), oracle,
                         f"corr-oracle/{method}/{regime}", exact=False,
                         atol=1e-5)


def test_client_reweighted_mean_vs_oracle(topo, problem):
    """Full-precision methods reweight the edge mean to the
    participating shares -- pinned against the oracle's renormalized
    weighted sum."""
    cc = H.client_cfg(1, 1, 4, "sampled_weighted")
    got, ew = H.run_hier(topo, problem, "hier_sgd", clients=cc)
    oracle = H.run_oracle(problem, "hier_sgd", clients=cc)
    H.assert_trees_equal(H.aggregate(got, ew), oracle,
                         "clients-oracle/hier_sgd", exact=False, atol=1e-5)


@pytest.mark.parametrize("kw", [{"error_feedback": True},
                                {"momentum": 0.9}],
                         ids=["ef", "momentum"])
def test_client_options_cross_layout(topo, problem, kw):
    """Beyond-paper options stay transport/layout-invariant under
    sampled participation too (EF exercises the participation-aware
    residual: abstaining clients transmitted nothing)."""
    cc = H.client_cfg(1, 1, 4, "sampled")
    ref, _ = H.run_hier(topo, problem, "dc_hier_signsgd", "ag_packed",
                        "tree", clients=cc, **kw)
    got, _ = H.run_hier(topo, problem, "dc_hier_signsgd", "fused",
                        "flat", clients=cc, **kw)
    H.assert_trees_equal(ref, got, f"client-options/{kw}")


def test_client_ef_abstaining_carries_residual(topo, problem):
    """EF semantics under participation: a client masked out of the
    round transmitted NOTHING, so its residual carries the full
    direction forward (e' = u) -- not u - scale*sgn(u) as if its sign
    had been sent.  Forced via the physical straggler mask with the
    virtual path active: the quorum is empty, so params are untouched
    and the residual equals the raw per-client gradients."""
    cc = H.client_cfg(1, 1, 2, "full")
    algo = H._algo("hier_signsgd", "ag_packed", "tree",
                   t_e=problem["t_e"], error_feedback=True, clients=cc)
    init_fn, step = hier.make_hier_step(topo, algo, H.make_bundle())
    state = jax.jit(init_fn)(problem["w0"], jax.random.PRNGKey(1))
    ew = jnp.ones((1,))
    dw = jnp.ones((1, 1))
    batch = {"train": {"x": problem["xs"][0], "y": problem["ys"][0]}}
    st2, _ = jax.jit(step)(state, batch, ew, dw, jnp.zeros((1, 1)))
    import numpy as np
    for k in problem["w0"]:   # empty quorum: v_q untouched, bitwise
        np.testing.assert_array_equal(np.asarray(st2.params[k]),
                                      np.asarray(state.params[k]))
    # e' == u: the per-client grads of the carved batch at w0
    def gfn(c):
        b = {"x": problem["xs"][0, 0, 0, c * 4:(c + 1) * 4],
             "y": problem["ys"][0, 0, 0, c * 4:(c + 1) * 4]}
        return jax.grad(H.loss_fn)(problem["w0"], b, None)
    for k in problem["w0"]:
        u = np.stack([np.asarray(gfn(c)[k]) for c in range(2)])[None]
        np.testing.assert_allclose(np.asarray(st2.ef[k]), u, rtol=2e-6,
                                   atol=1e-7)


# ---------------------------------------------------------------------------
# Streamed client sweep: mode="stream" loops clients inside the step,
# folding each sign plane into a persistent integer tally -- it must be
# BITWISE identical to the merged voter axis everywhere it exists.
# ---------------------------------------------------------------------------


def _stream(cc):
    return dataclasses.replace(cc, mode="stream")


@pytest.mark.parametrize("method,transport,layout", H.matrix_cells())
def test_stream_matches_merged_matrix(topo, problem, method, transport,
                                      layout):
    """HEADLINE streamed contract: every matrix cell (sign AND mean
    methods, all transports x layouts) is bitwise identical between the
    merged voter axis and the streamed in-step client loop, under the
    hardest regime (K=4, Bernoulli(0.5) participation, unequal |D_qk|
    weights).  The merged cell stays the pinned reference."""
    cc = H.client_cfg(1, 1, 4, "sampled_weighted")
    ref, _ = H.run_hier(topo, problem, method, transport, layout,
                        clients=cc)
    got, _ = H.run_hier(topo, problem, method, transport, layout,
                        clients=_stream(cc))
    H.assert_trees_equal(ref, got,
                         f"stream/{method}/{transport}/{layout}")


@pytest.mark.parametrize("regime", H.CLIENT_REGIMES)
def test_stream_matches_merged_regimes(topo, problem, regime):
    """Every participation regime streams bitwise -- the per-round pinned
    masks, |D_qk| weights and participating shares are computed once and
    sliced per client inside the loop."""
    cc = H.client_cfg(1, 1, 4, regime)
    ref, _ = H.run_hier(topo, problem, "dc_hier_signsgd", "fused", "flat",
                        clients=cc)
    got, _ = H.run_hier(topo, problem, "dc_hier_signsgd", "fused", "flat",
                        clients=_stream(cc))
    H.assert_trees_equal(ref, got, f"stream-regime/{regime}")


@pytest.mark.parametrize("layout", H.LAYOUTS)
@pytest.mark.parametrize("kw", [{"error_feedback": True},
                                {"momentum": 0.9}, {"decay": True}],
                         ids=["ef", "momentum", "decay"])
def test_stream_options(topo, problem, layout, kw):
    """Per-client EF residuals and momentum live on the [P, D, K] voter
    axis in BOTH modes; the streamed loop slices and writes back one
    client at a time and must land on the identical state (EF under
    fused transport drops to the per-leaf tally route, mirroring the
    merged fallback to the tree vote)."""
    cc = H.client_cfg(1, 1, 4, "sampled")
    ref, _ = H.run_hier(topo, problem, "dc_hier_signsgd", "fused", layout,
                        clients=cc, **kw)
    got, _ = H.run_hier(topo, problem, "dc_hier_signsgd", "fused", layout,
                        clients=_stream(cc), **kw)
    H.assert_trees_equal(ref, got, f"stream-options/{kw}/{layout}")


def test_stream_k1_equivalence(topo, problem, refs):
    """K=1 through the ACTIVE streamed machinery (a fori_loop of one
    client) is still bitwise the legacy trajectory."""
    cc = _stream(H.client_cfg(1, 1, 1, "full"))
    assert cc.active and cc.mode == "stream"
    for method in ("dc_hier_signsgd", "hier_sgd"):
        ref, _ = _ref(refs, topo, problem, method)
        got, _ = H.run_hier(topo, problem, method, clients=cc)
        H.assert_trees_equal(ref, got, f"stream-k1/{method}")


def test_stream_mode_validated():
    with pytest.raises(ValueError, match="mode"):
        dataclasses.replace(H.client_cfg(1, 1, 4, "full"), mode="bogus")


def test_clients_reject_fsdp(topo):
    bundle = H.make_bundle("fsdp")
    algo = hier.AlgoConfig(clients=H.client_cfg(1, 1, 2, "sampled"))
    with pytest.raises(ValueError, match="replicated"):
        hier.make_hier_step(topo, algo, bundle)


def _count_vote_updates(topo, problem, layout, monkeypatch):
    """Trace one fused train step; return the mu of each vote_update
    kernel invocation (the kernel route is forced via interpret mode)."""
    monkeypatch.setenv("REPRO_FUSED_PALLAS", "interpret")
    calls = []
    orig = _vu.vote_update

    def counting(*args, **kw):
        calls.append(kw.get("mu"))
        return orig(*args, **kw)

    monkeypatch.setattr(_vu, "vote_update", counting)
    algo = H._algo("dc_hier_signsgd", "fused", layout,
                   t_e=problem["t_e"])
    init_fn, step = hier.make_hier_step(topo, algo, H.make_bundle())
    state = init_fn(problem["w0"], jax.random.PRNGKey(1))
    ew = jnp.ones((1,))
    dw = mask = jnp.ones((1, 1))
    batch = {"train": {"x": problem["xs"][0], "y": problem["ys"][0]}}
    jax.make_jaxpr(lambda s, b: step(s, b, ew, dw, mask))(state, batch)
    return calls, algo


def test_flat_fused_single_vote_update(topo, problem, monkeypatch):
    """Acceptance: the flat update path issues exactly ONE vote_update
    over the whole-model buffer per local step, with the real mu folded
    in (the update IS the kernel's read-modify-write) -- while the tree
    layout uses the kernel only as a vote (mu = -1) and updates per
    leaf."""
    calls, algo = _count_vote_updates(topo, problem, "flat", monkeypatch)
    assert calls == [algo.mu], calls
    calls, _ = _count_vote_updates(topo, problem, "tree", monkeypatch)
    assert calls == [-1.0], calls


@pytest.mark.parametrize("method,opts", [
    ("dc_hier_signsgd", {}),
    ("dc_hier_signsgd", {"error_feedback": True}),
    ("hier_signsgd", {}),
    ("hier_signsgd", {"error_feedback": True, "momentum": 0.9}),
    ("scaffold_hier_signsgd", {}),
    ("mtgc_hier_signsgd", {}),
    ("hier_sgd", {}),
])
@pytest.mark.parametrize("layout", H.LAYOUTS)
def test_state_structure(topo, problem, method, opts, layout):
    """Regression: state entries are allocated only when used -- delta
    only for DC (or FSDP), correction buffers only for scaffold/mtgc
    (no scaffold/mtgc slots under dc and no DC anchor under
    scaffold/mtgc), EF residual only under error_feedback, momentum
    only when momentum > 0 -- in both state layouts."""
    algo = H._algo(method, "ag_packed", layout, **opts)
    init_fn, step = hier.make_hier_step(topo, algo, H.make_bundle())
    state = init_fn(problem["w0"], jax.random.PRNGKey(0))
    assert (state.delta is not None) == (method == "dc_hier_signsgd")
    assert (state.delta_next is not None) == (method == "dc_hier_signsgd")
    corr = method in hier.CLIENT_CORRECTION_METHODS
    assert (state.corr_cl is not None) == corr
    assert (state.corr_edge is not None) == corr
    assert (state.ef is not None) == opts.get("error_feedback", False)
    assert (state.mom is not None) == (opts.get("momentum", 0.0) > 0)
    if layout == "flat":
        assert isinstance(state.params, flatbuf.FlatState)
        for fs in (state.delta, state.ef, state.mom, state.corr_cl,
                   state.corr_edge):
            assert fs is None or isinstance(fs, flatbuf.FlatState)
        if state.corr_cl is not None:
            assert state.corr_cl.buf.dtype == algo.delta_dtype
            # per-client buffer on the voter axis, per-edge on master
            assert state.corr_cl.buf.shape[:2] == (1, 1)
            assert state.corr_cl.batch_dims == 2
        if state.delta is not None:
            assert state.delta.buf.dtype == algo.delta_dtype
            # aux buffers re-label the layout with their own dtype
            assert state.delta.layout.dtype == algo.delta_dtype
            assert all(s.dtype == algo.delta_dtype
                       for s in state.delta.layout.slots)
        if state.ef is not None:
            assert state.ef.buf.shape == (1, 1, state.params.layout.n_pad)
    # the step runs and preserves the structure
    ew = jnp.ones((1,))
    dw = mask = jnp.ones((1, 1))
    batch = {"train": {"x": problem["xs"][0], "y": problem["ys"][0]}}
    state2, _ = jax.jit(step)(state, batch, ew, dw, mask)
    assert (jax.tree_util.tree_structure(state2)
            == jax.tree_util.tree_structure(state))


# ---------------------------------------------------------------------------
# Chaos cells: a deterministic churn schedule (client kill, straggler
# demotion, heartbeat loss, pod kill, recoveries -- see
# H.chaos_injector) is compiled to per-step membership arrays and fed
# through every train-step combination AND the grown ref_fed oracle.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def chaos(problem):
    """Compiled chaos schedule for the fast cell (P=1, D=1, K=2)."""
    cc = H.client_cfg(1, 1, 2, "full")
    inj = H.chaos_injector(1, 1, 2, problem["t_e"])
    return cc, inj, H.chaos_arrays(problem, cc, inj)


CHAOS_METHODS = ["hier_signsgd", "dc_hier_signsgd",
                 "scaffold_hier_signsgd", "mtgc_hier_signsgd"]


@pytest.mark.parametrize("method", CHAOS_METHODS + ["hier_sgd"])
def test_chaos_vs_oracle(topo, problem, chaos, method):
    """HEADLINE churn contract: under the chaos schedule -- client kill,
    straggler demotion, fail-open window, heartbeat-loss sweep, partial
    recovery -- the cloud-aggregated model matches the grown ref_fed
    oracle driven by the SAME compiled membership arrays
    (device_mask_steps per local step, edge_weights_agg for the closing
    cloud aggregation).  Sign methods are EXACT (bitwise): abstention
    is integer arithmetic on both sides.  hier_sgd accumulates the
    renormalized mean in a different association order -> float
    tolerance."""
    cc, inj, arrays = chaos
    ref, _ = H.run_hier_chaos(topo, problem, method, clients=cc,
                              arrays=arrays)
    oracle = H.run_oracle_chaos(problem, method, cc, arrays)
    exact = method != "hier_sgd"
    H.assert_trees_equal(H.aggregate(ref, arrays[-1].edge_weights),
                         oracle, f"chaos-oracle/{method}", exact=exact,
                         atol=1e-6)


@pytest.mark.parametrize("transport", H.SIGN_TRANSPORTS)
@pytest.mark.parametrize("layout", H.LAYOUTS)
@pytest.mark.parametrize("mode", ["merged", "stream"])
def test_chaos_cross_cells(topo, problem, chaos, transport, layout,
                           mode):
    """Every transport x layout x client-mode cell runs the SAME churn
    schedule bitwise: membership is a runtime input, so the abstention
    pattern is identical no matter how the votes move or the state is
    laid out."""
    cc, inj, arrays = chaos
    ref, _ = H.run_hier_chaos(topo, problem, "dc_hier_signsgd",
                              clients=cc, arrays=arrays)
    ccm = cc if mode == "merged" else _stream(cc)
    got, _ = H.run_hier_chaos(topo, problem, "dc_hier_signsgd",
                              transport, layout, clients=ccm,
                              arrays=arrays)
    H.assert_trees_equal(
        ref, got, f"chaos-x/{transport}/{layout}/{mode}")


def test_chaos_weighted_sampled_vs_oracle(topo, problem):
    """Churn composed with the hardest participation regime --
    Bernoulli(0.5) sampling AND unequal |D_qk| weights -- stays exact
    vs the oracle (the effective mask is sampled AND live; the weighted
    popcount is still integer) and bitwise across transports."""
    cc = H.client_cfg(1, 1, 2, "sampled_weighted")
    inj = H.chaos_injector(1, 1, 2, problem["t_e"])
    arrays = H.chaos_arrays(problem, cc, inj)
    ref, _ = H.run_hier_chaos(topo, problem, "dc_hier_signsgd",
                              clients=cc, arrays=arrays)
    got, _ = H.run_hier_chaos(topo, problem, "dc_hier_signsgd", "fused",
                              "flat", clients=_stream(cc), arrays=arrays)
    H.assert_trees_equal(ref, got, "chaos-weighted/fused-flat-stream")
    oracle = H.run_oracle_chaos(problem, "dc_hier_signsgd", cc, arrays)
    H.assert_trees_equal(H.aggregate(ref, arrays[-1].edge_weights),
                         oracle, "chaos-weighted-oracle", exact=True)


@pytest.mark.parametrize("method", ["dc_hier_signsgd",
                                    "scaffold_hier_signsgd"])
def test_chaos_kill_restore_replay(topo, problem, chaos, method,
                                   tmp_path):
    """Kill-restore-replay is BITWISE invisible: a nan-loss event fires
    mid-trajectory, the driver restores the newest checkpoint
    (checkpoint/store.py) and replays -- and because batches are
    cursor-addressable and membership replays from the compiled
    schedule, the final state is bitwise the uninterrupted trajectory
    (correction state, EF carry-forward and all)."""
    cc, _, arrays = chaos
    ref, _ = H.run_hier_chaos(topo, problem, method, clients=cc,
                              arrays=arrays)
    inj_n = H.chaos_injector(1, 1, 2, problem["t_e"], nan_step=5)
    got, _ = H.run_hier_chaos(topo, problem, method, clients=cc,
                              injector=inj_n, arrays=arrays,
                              ckpt_dir=str(tmp_path),
                              ckpt_every=problem["t_e"])
    H.assert_trees_equal(ref, got, f"chaos-replay/{method}")


def test_chaos_membership_zero_recompilation(topo, problem, chaos):
    """Membership churn causes ZERO recompilations: the (weights, mask)
    arrays are runtime inputs with fixed shapes, so the step traces
    exactly once across every membership change in the schedule."""
    cc, inj, arrays = chaos
    traces = []

    algo = H._algo("dc_hier_signsgd", "ag_packed", "tree",
                   t_e=problem["t_e"], clients=cc)
    init_fn, step = hier.make_hier_step(topo, algo, H.make_bundle())

    def counting_step(state, batch, ew, dw, mask):
        traces.append(1)
        return step(state, batch, ew, dw, mask)

    jstep = jax.jit(counting_step)
    state = jax.jit(init_fn)(problem["w0"], jax.random.PRNGKey(1))
    assert len({(a.edge_weights.tobytes(), a.mask.tobytes())
                for a in arrays}) > 1, "schedule never changes membership"
    for s in range(problem["rounds"] * problem["t_e"]):
        a = arrays[s]
        batch = {"train": {"x": problem["xs"][s], "y": problem["ys"][s]}}
        state, _ = jstep(state, batch, jnp.asarray(a.edge_weights),
                         jnp.asarray(a.dev_weights),
                         jnp.asarray(a.mask))
    assert sum(traces) == 1, f"recompiled: {sum(traces)} traces"


# ---------------------------------------------------------------------------
# Overlapped cloud tier (cloud_overlap="overlap"): the round boundary
# splits into issue (snapshot + start the cross-pod mean) and commit
# (apply the aggregate issued one boundary earlier); edges keep
# local-stepping on their local models while the mean is in flight.
# The extended ref_fed oracle runs the SAME lagged schedule
# (FedState.w_inflight mirrors TrainState.agg_next).
# ---------------------------------------------------------------------------


def test_overlap_sync_mode_is_noop(topo, problem, refs):
    """cloud_overlap="sync" (explicit) is bitwise the default trajectory
    -- the schedule layer's lag=0 path IS the pre-existing prologue, on
    both state layouts."""
    ref, _ = _ref(refs, topo, problem, "dc_hier_signsgd")
    for layout in H.LAYOUTS:
        got, _ = H.run_hier(topo, problem, "dc_hier_signsgd", "ag_packed",
                            layout, cloud_overlap="sync")
        H.assert_trees_equal(ref, got, f"overlap-sync-noop/{layout}")


def test_overlap_differs_from_sync(topo, problem, refs):
    """Sanity: the lagged commit actually changes the trajectory (guards
    against a schedule layer that silently commits the fresh issue)."""
    import numpy as np
    ref, _ = _ref(refs, topo, problem, "dc_hier_signsgd")
    got, _ = H.run_hier(topo, problem, "dc_hier_signsgd",
                        cloud_overlap="overlap")
    assert any(not np.array_equal(np.asarray(ref[k]), np.asarray(got[k]))
               for k in ref)


OVERLAP_METHODS = ["hier_signsgd", "dc_hier_signsgd",
                   "scaffold_hier_signsgd", "mtgc_hier_signsgd"]


@pytest.mark.parametrize("method", OVERLAP_METHODS)
def test_overlap_matrix_vs_oracle(topo, problem, method):
    """HEADLINE overlap contract: every sign method x transport x
    layout x merged/stream cell runs the lagged schedule bitwise
    identically, and the closing-boundary aggregate of the final edge
    models is EXACT vs the extended oracle's in-flight aggregate
    (``w_inflight``) -- the committed model lags one boundary behind on
    both sides by construction."""
    cc = H.client_cfg(1, 1, 2, "full")
    ref = ew = None
    for transport in H.SIGN_TRANSPORTS:
        for layout in H.LAYOUTS:
            for mode in ("merged", "stream"):
                ccm = cc if mode == "merged" else _stream(cc)
                got, w = H.run_hier(topo, problem, method, transport,
                                    layout, clients=ccm,
                                    cloud_overlap="overlap")
                if ref is None:
                    ref, ew = got, w
                H.assert_trees_equal(
                    ref, got,
                    f"overlap/{method}/{transport}/{layout}/{mode}")
    oracle = H.run_oracle(problem, method, clients=cc,
                          cloud_overlap="overlap")
    H.assert_trees_equal(H.aggregate(ref, ew), oracle,
                         f"overlap-oracle/{method}", exact=True)


def test_overlap_sgd_vs_oracle(topo, problem):
    """The full-precision mean method under the lagged schedule (float
    tolerance: the oracle accumulates the edge mean in a different
    association order)."""
    cc = H.client_cfg(1, 1, 2, "full")
    got, ew = H.run_hier(topo, problem, "hier_sgd", clients=cc,
                         cloud_overlap="overlap")
    oracle = H.run_oracle(problem, "hier_sgd", clients=cc,
                          cloud_overlap="overlap")
    H.assert_trees_equal(H.aggregate(got, ew), oracle,
                         "overlap-oracle/hier_sgd", exact=False,
                         atol=1e-6)


def test_overlap_staged_slot_semantics(topo, problem):
    """The staged slot IS the issued aggregate: at init it is a bitwise
    copy of w0 (so the step-0 commit runs round 0 from w0, exactly like
    sync), and at the end of the run it holds the aggregate issued at
    the LAST executed boundary -- at P=1 / unit edge weight, bitwise
    the edge-model snapshot taken there."""
    import numpy as np
    t_e = problem["t_e"]
    algo = H._algo("dc_hier_signsgd", "ag_packed", "tree", t_e=t_e,
                   cloud_overlap="overlap")
    init_fn, step = hier.make_hier_step(topo, algo, H.make_bundle())
    state = jax.jit(init_fn)(problem["w0"], jax.random.PRNGKey(1))
    for k in problem["w0"]:   # staged copy of the initial edge params
        assert np.array_equal(np.asarray(state.agg_next[k]),
                              np.asarray(state.params[k]))
    ew = jnp.ones((1,))
    dw = mask = jnp.ones((1, 1))
    jstep = jax.jit(step)
    xs, ys = problem["xs"], problem["ys"]
    snap = None
    for s in range(problem["rounds"] * t_e):
        anchor = s - s % t_e
        batch = {"train": {"x": xs[s], "y": ys[s]},
                 "anchor": {"x": xs[anchor], "y": ys[anchor]}}
        state, _ = jstep(state, batch, ew, dw, mask)
        if s == 2 * t_e - 1:    # end of round 1: the NEXT boundary issues
            snap = jax.tree.map(np.asarray, state.params)
    H.assert_trees_equal(snap, jax.tree.map(np.asarray, state.agg_next),
                         "overlap-staged-slot")


def test_overlap_validation(topo):
    """Incompatible regimes reject at build time with actionable
    messages (the dryrun/launcher SKIP contracts lean on these)."""
    with pytest.raises(ValueError, match="cloud_overlap"):
        hier.AlgoConfig(cloud_overlap="bogus")
    with pytest.raises(ValueError, match="replicated"):
        hier.make_hier_step(topo, hier.AlgoConfig(cloud_overlap="overlap"),
                            H.make_bundle("fsdp"))
    with pytest.raises(ValueError, match="prologue"):
        hier.make_hier_step(topo, hier.AlgoConfig(cloud_overlap="overlap"),
                            H.make_bundle(), sync="never")


@pytest.mark.parametrize("method", CHAOS_METHODS)
def test_overlap_chaos_vs_oracle(topo, problem, chaos, method):
    """Churn while an aggregate is in flight: the chaos schedule runs
    under the lagged commit, and the closing aggregate stays EXACT vs
    the oracle -- commit weights are pinned to issue-time membership
    (``edge_weights_agg``), so mid-flight kills change WHO votes next
    round but never what lands."""
    cc, inj, arrays = chaos
    ref, _ = H.run_hier_chaos(topo, problem, method, clients=cc,
                              arrays=arrays, cloud_overlap="overlap")
    oracle = H.run_oracle_chaos(problem, method, cc, arrays,
                                cloud_overlap="overlap")
    H.assert_trees_equal(H.aggregate(ref, arrays[-1].edge_weights),
                         oracle, f"overlap-chaos-oracle/{method}",
                         exact=True)


@pytest.mark.parametrize("transport", H.SIGN_TRANSPORTS)
@pytest.mark.parametrize("layout", H.LAYOUTS)
def test_overlap_chaos_cross_cells(topo, problem, chaos, transport,
                                   layout):
    """Transport x layout invariance holds under churn + overlap too:
    the staged slot rides the same schedule no matter how votes move
    or where the state lives (flat cells exercise the FlatState
    agg_next slot)."""
    cc, inj, arrays = chaos
    ref, _ = H.run_hier_chaos(topo, problem, "dc_hier_signsgd",
                              clients=cc, arrays=arrays,
                              cloud_overlap="overlap")
    got, _ = H.run_hier_chaos(topo, problem, "dc_hier_signsgd",
                              transport, layout, clients=cc,
                              arrays=arrays, cloud_overlap="overlap")
    H.assert_trees_equal(ref, got,
                         f"overlap-chaos-x/{transport}/{layout}")


@pytest.mark.parametrize("method", ["dc_hier_signsgd",
                                    "scaffold_hier_signsgd"])
def test_overlap_kill_restore_replay(topo, problem, chaos, method,
                                     tmp_path):
    """Mid-flight kill-restore-replay is BITWISE invisible: with
    ckpt_every=2 and the nan event at step 5, the restore lands at
    step 4 -- mid-round, with an aggregate staged in agg_next -- and
    the replayed trajectory is bitwise the uninterrupted one (the
    checkpoint manifest records the staged slot like any other state
    leaf)."""
    cc, _, arrays = chaos
    ref, _ = H.run_hier_chaos(topo, problem, method, clients=cc,
                              arrays=arrays, cloud_overlap="overlap")
    inj_n = H.chaos_injector(1, 1, 2, problem["t_e"], nan_step=5)
    got, _ = H.run_hier_chaos(topo, problem, method, clients=cc,
                              injector=inj_n, arrays=arrays,
                              ckpt_dir=str(tmp_path), ckpt_every=2,
                              cloud_overlap="overlap")
    H.assert_trees_equal(ref, got, f"overlap-replay/{method}")


# ---------------------------------------------------------------------------
# Intra-edge heterogeneity axis: per-client data distributions INSIDE an
# edge (make_problem(alpha_client=...)) and server-side edge
# re-assignment -- the distributed row-block regrouping
# (clients.regroup_clients on the carve coordinates) pinned against the
# oracle's per-client data assignment (ref_fed.regroup_client_data).
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def skew_problem():
    """K=4 virtual clients per slice, each regressing on its OWN target
    (a Dirichlet(0.25) prototype mixture): the carve recovers genuinely
    distinct per-client distributions."""
    return H.make_problem(1, 1, clients=4, alpha_client=0.25)


def test_intra_edge_skew_changes_data(problem, skew_problem):
    """Guard: the axis is live -- per-client targets differ from the
    legacy per-pod problem AND from each other (client row blocks of
    one slice batch have distinct statistics)."""
    import numpy as np
    assert not np.array_equal(np.asarray(problem["ys"]),
                              np.asarray(skew_problem["ys"]))
    ys = np.asarray(skew_problem["ys"])[:, 0, 0]      # [S, b, DOUT]
    per_client = ys.reshape(ys.shape[0], 4, -1).mean(axis=(0, 2))
    assert len(set(np.round(per_client, 6))) == 4, per_client


@pytest.mark.parametrize("method", ["dc_hier_signsgd",
                                    "scaffold_hier_signsgd"])
@pytest.mark.parametrize("mode", ["merged", "stream"])
def test_intra_edge_skew_vs_oracle(topo, skew_problem, method, mode):
    """Intra-edge skew x {merged, stream} x {dc, scaffold}: the new data
    axis changes WHAT each client holds, never the update arithmetic --
    cells stay bitwise across the fused/flat route and EXACT vs the
    grown ref_fed oracle hosting the same per-client distributions."""
    cc = H.client_cfg(1, 1, 4, "full")
    ccm = cc if mode == "merged" else _stream(cc)
    ref, ew = H.run_hier(topo, skew_problem, method, clients=ccm)
    got, _ = H.run_hier(topo, skew_problem, method, "fused", "flat",
                        clients=ccm)
    H.assert_trees_equal(ref, got, f"skew/{method}/{mode}/fused-flat")
    oracle = H.run_oracle(skew_problem, method, clients=cc)
    H.assert_trees_equal(H.aggregate(ref, ew), oracle,
                         f"skew-oracle/{method}/{mode}", exact=True)


def test_edge_assignment_regroup_parity(topo, skew_problem):
    """The two halves of a server-side edge re-assignment agree
    BITWISE: the distributed step fed the permuted row blocks
    (clients.regroup_clients via regroup_problem) lands exactly on the
    oracle fed the permuted nested client lists
    (ref_fed.regroup_client_data via run_oracle(assignment=...)), and
    slice-then-permute equals permute-then-slice on the oracle side."""
    import numpy as np
    order = np.array([2, 0, 3, 1])
    moved = H.regroup_problem(skew_problem, order)
    assert not np.array_equal(np.asarray(moved["ys"]),
                              np.asarray(skew_problem["ys"]))
    cc = H.client_cfg(1, 1, 4, "full")
    ref, ew = H.run_hier(topo, moved, "dc_hier_signsgd", clients=cc)
    oracle = H.run_oracle(skew_problem, "dc_hier_signsgd", clients=cc,
                          assignment=order)
    H.assert_trees_equal(H.aggregate(ref, ew), oracle, "assign-oracle",
                         exact=True)
    oracle2 = H.run_oracle(moved, "dc_hier_signsgd", clients=cc)
    H.assert_trees_equal(oracle, oracle2, "assign-slice-vs-permute",
                         exact=True)


def _run_check(script: str, want: str):
    env = {"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin", "HOME": "/tmp"}
    r = subprocess.run(
        [sys.executable, str(HELPERS / script)],
        capture_output=True, text=True, timeout=1800, env=env)
    assert r.returncode == 0, (
        f"{script} failed:\nSTDOUT:\n{r.stdout[-4000:]}\n"
        f"STDERR:\n{r.stderr[-4000:]}")
    assert want in r.stdout


@pytest.mark.slow
def test_parity_matrix_multidevice():
    """The full matrix on an 8-CPU 2x2x2 mesh: cross-transport /
    cross-layout bitwise, oracle, straggler masks, EF/momentum, FSDP,
    plus the UNEVEN-TP-leaf cell (odd hidden dim: the flat cells run
    the padded-shard layout and must stay bitwise vs tree state).
    The flat cells run the model-axis-SHARDED layout there (model=2)."""
    _run_check("parity_matrix_check.py", "parity matrix OK")


@pytest.mark.slow
def test_fused_multichip_sharded():
    """The multi-chip fused acceptance cell (8-CPU 2x2x2 mesh): sharded
    flat layout engaged, bitwise parity on the jnp AND per-rank kernel
    (interpret) routes, and NO model-axis all-gather in the optimized
    HLO of the fused/flat train step -- strictly (unattributed
    collectives fail the check), for the even AND the uneven
    (padded-shard) cells (benchmarks.hlo_analysis.assert_axis_free)."""
    _run_check("sharded_fused_check.py", "sharded fused check OK")
