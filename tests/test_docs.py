"""Docs stay true: link integrity + README support matrix consistency.

The README's transport x method x state_layout matrix is the public
contract; this test pins it to the ACTUAL parametrization of the parity
suite (``tests/helpers/parity_harness.matrix_cells``), so a cell can
only be advertised if the bitwise parity tests run it -- and vice
versa.  CI runs this file in the standalone docs job.
"""
import pathlib
import re
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent / "helpers"))
import parity_harness as H  # noqa: E402

from repro.core import hier  # noqa: E402

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md", ROOT / "ROADMAP.md",
             *sorted((ROOT / "docs").glob("*.md"))]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def test_docs_exist():
    assert (ROOT / "README.md").is_file()
    assert (ROOT / "docs" / "architecture.md").is_file()
    assert (ROOT / "docs" / "benchmarks.md").is_file()


def test_markdown_links_resolve():
    """Every relative link in README/ROADMAP/docs points at a real file."""
    missing = []
    for doc in DOC_FILES:
        for target in _LINK.findall(doc.read_text()):
            if "://" in target or target.startswith(("#", "mailto:")):
                continue
            path = (doc.parent / target.split("#")[0]).resolve()
            if not path.exists():
                missing.append(f"{doc.relative_to(ROOT)} -> {target}")
    assert not missing, f"dangling doc links: {missing}"


def _readme_matrix():
    """Parse the support-matrix table: {method: {column: cell}}."""
    text = (ROOT / "README.md").read_text()
    rows = [ln for ln in text.splitlines()
            if ln.startswith("|") and "`" in ln]
    header = next(ln for ln in rows if "method" in ln)
    cols = [c.strip().strip("`") for c in header.strip("|").split("|")]
    matrix = {}
    for ln in rows:
        cells = [c.strip() for c in ln.strip("|").split("|")]
        m = re.match(r"`(\w+)`", cells[0])
        if not m or m.group(1) == cols[0]:
            continue
        matrix[m.group(1)] = {
            col: cell.strip("`") for col, cell in zip(cols[1:], cells[1:])}
    return matrix


def test_readme_matrix_matches_parity_parametrization():
    matrix = _readme_matrix()
    sign_methods = set(hier.SIGN_METHODS)
    for method, transport, layout in H.matrix_cells():
        assert method in matrix, f"README matrix is missing {method}"
        row = matrix[method]
        assert row.get(layout) == "✓", (
            f"README matrix: {method} must advertise state_layout "
            f"{layout!r} (tested by test_parity_matrix)")
        if method in sign_methods:
            assert row.get(transport) == "✓", (
                f"README matrix: {method} must advertise transport "
                f"{transport!r} (tested by test_parity_matrix)")
        else:
            assert row.get(transport) == "mean", (
                f"README matrix: {method} aggregates by weighted mean")
    # no over-advertising: every ✓ transport cell is in the test matrix
    tested = {(m, t) for m, t, _ in H.matrix_cells()}
    for method, row in matrix.items():
        for transport in H.SIGN_TRANSPORTS:
            if row.get(transport) == "✓":
                assert (method, transport) in tested, (
                    f"README advertises untested cell "
                    f"{method}/{transport}")


def test_padded_shard_rule_documented():
    """The uneven-TP-leaf contract is pinned: the architecture doc's
    bucket-coordinate-space section documents the padded-shard rule
    (shard_pad, pad/unpad boundary helpers) and the README transport
    matrix advertises it."""
    arch = (ROOT / "docs" / "architecture.md").read_text()
    assert "padded-shard rule" in arch
    assert "shard_pad" in arch
    assert "pad_tree" in arch and "unpad_tree" in arch
    readme = (ROOT / "README.md").read_text()
    assert "padded-shard rule" in readme
    # the old caveat is gone from the living docs (ROADMAP keeps it
    # only as a struck-through history line): no doc may still describe
    # UNEVEN leaves as part of the per-bucket-copy fallback
    for doc in DOC_FILES:
        if doc.name == "ROADMAP.md":
            continue
        text = doc.read_text()
        assert "uneven or zero-size" not in text, doc
        assert "replicated / uneven / zero-size" not in text, doc


def test_virtual_client_participation_documented():
    """The virtual-client/participation contract is pinned: the README
    table lists every sampling mode the config accepts, both docs carry
    the weighted-popcount + empty-quorum-abstains vote semantics, and
    the architecture doc records the pinned (seed, round) sampling
    scheme and the tally-dtype promotion rule."""
    from repro.core.clients import PARTICIPATION_MODES
    readme = (ROOT / "README.md").read_text()
    arch = (ROOT / "docs" / "architecture.md").read_text()
    for mode in PARTICIPATION_MODES:
        assert f"`{mode}`" in readme, f"README participation table: {mode}"
    assert "--clients_per_device" in readme
    for text, name in ((readme, "README"), (arch, "architecture.md")):
        assert "weighted popcount" in text, name
        assert "abstains" in text, name
        assert "sum(w)" in text, name           # tally-range contract
    assert "splitmix32" in readme and "splitmix32" in arch
    assert "partition-stable" in arch            # why not jax.random
    assert "d*K + c" in readme and "d*K + c" in arch  # voter coordinates
    assert "weight_bound" in arch                # static promotion rule
    assert "sgn(0) = +1" in readme               # weighted-tie convention


def test_streamed_client_sweep_documented():
    """The streamed-sweep contract is pinned: the README matrix carries
    a `stream` column with every method checked (both modes run every
    cell bitwise), both docs state the O(model/32 + tally) memory
    bound, and the architecture doc records the deferred-threshold
    bitwise contract and the decision rule."""
    from repro.core.clients import CLIENT_MODES
    assert set(CLIENT_MODES) == {"merged", "stream"}
    readme = (ROOT / "README.md").read_text()
    arch = (ROOT / "docs" / "architecture.md").read_text()
    matrix = _readme_matrix()
    for method in {m for m, _, _ in H.matrix_cells()}:
        row = matrix[method]
        assert row.get("stream") == "✓", (
            f"README matrix: {method} must advertise client mode "
            f"'stream' (tested by test_stream_matches_merged_matrix)")
    assert "--client_mode" in readme
    for text, name in ((readme, "README"), (arch, "architecture.md")):
        assert "O(model/32 + tally)" in text, name
        assert "bitwise" in text, name
    assert "fori_loop" in arch
    assert "tally_dtype" in arch                 # promotion rule shared
    assert "deferred" in arch                    # threshold after loop
    assert "fused_tally_finish" in arch          # one collective/step
    assert "bench_clients.py" in readme and "bench_clients.py" in arch
    assert "BENCH_clients.json" in readme


def test_correction_slot_documented():
    """The drift-correction method axis is pinned: the architecture doc
    carries the pre-sign correction slot state table (which buffer,
    which timescale, which tier owns the update), both docs name the
    bias study artifacts, and the EF carry-forward participation
    contract is stated."""
    readme = (ROOT / "README.md").read_text()
    arch = (ROOT / "docs" / "architecture.md").read_text()
    assert "pre-sign correction slot" in arch
    for buf in ("corr_cl", "corr_edge"):         # state-table rows
        assert buf in arch, buf
    for method in hier.CLIENT_CORRECTION_METHODS:
        assert f"`{method}`" in arch, method
        assert f"`{method}`" in readme, method
    assert "cloud_period" in arch and "--cloud_period" in readme
    assert "carry-forward" in arch and "carry-forward" in readme
    assert "bias_study.py" in readme and "bias_study.py" in arch
    assert "BENCH_bias.json" in readme and "BENCH_bias.json" in arch
    # the per-method wire accounting is documented next to the study
    assert "downlink_bits" in arch


def test_heterogeneity_clustering_documented():
    """The heterogeneity/clustering contract is pinned: the architecture
    doc carries the Heterogeneity & clustering section (two-level
    Dirichlet, bitwise None/inf gate, largest-remainder apportionment,
    signature privacy, no-RNG deterministic clustering, regrouping as a
    pure permutation), the README scenario table lists every
    edge-assign mode the config accepts, and both docs name the CLI
    flags and the grown test tier."""
    from repro.data.cluster import EDGE_ASSIGN_MODES
    readme = (ROOT / "README.md").read_text()
    arch = (ROOT / "docs" / "architecture.md").read_text()
    assert "Heterogeneity & clustering" in arch
    for mode in EDGE_ASSIGN_MODES:
        assert f"`{mode}`" in readme, f"README edge_assign table: {mode}"
    for text, name in ((readme, "README"), (arch, "architecture.md")):
        assert "--alpha_client" in text, name
        assert "--edge_assign" in text, name
        assert "bitwise" in text, name              # the None/inf gate
    assert "largest-remainder" in arch
    assert "largest_remainder" in arch              # the helper by name
    assert "label histogram" in arch                # signature kinds
    assert "sketch" in arch
    assert "never leave the client" in arch         # privacy contract
    assert "no RNG" in arch                         # determinism contract
    assert "lexicographic" in arch
    assert "regroup_clients" in arch                # live regroup
    assert "regroup_client_data" in arch            # oracle counterpart
    assert "validate_scenario" in arch              # CLI rejection hook
    assert "test_data_hetero.py" in arch and "test_data_hetero.py" in \
        readme
    assert "bias_study_v2" in arch                  # the 2x2 artifact
    # the clustered mode's precondition is stated wherever the flag is
    assert "--clients_per_device" in readme


def test_elastic_chaos_documented():
    """The elastic-runtime/chaos contract is pinned: both docs carry
    the chaos-schedule section (event kinds as data, zero-recompilation
    churn, fail-open, kill-restore-replay bitwise), the CLI flags are
    named, and every documented event kind exists in the engine."""
    from repro.runtime.chaos import EVENT_KINDS
    readme = (ROOT / "README.md").read_text()
    arch = (ROOT / "docs" / "architecture.md").read_text()
    assert "Elastic runtime & chaos schedules" in arch
    for text, name in ((readme, "README"), (arch, "architecture.md")):
        assert "--chaos" in text, name
        assert "fail" in text and "open" in text, name
        assert "zero recompilations" in text or "recompilation-free" in \
            text, name
        assert "chaos_report.py" in text, name
        assert "chaos_cells.json" in text or "chaos report" in text, name
    # the architecture doc names every event kind the engine accepts
    for kind in EVENT_KINDS:
        assert f"`{kind}`" in arch, f"architecture.md: event kind {kind}"
    assert "replay_membership" in arch           # deterministic replay
    assert "device_mask_steps" in arch           # oracle growth
    assert "edge_weights_agg" in arch            # closing-round weights
    assert "may_restore" in arch and "record_restore" in arch
    assert "kill-restore-replay" in readme and "kill-restore-replay" in arch


def test_cloud_overlap_documented():
    """The cloud sync-schedule contract is pinned: the architecture doc
    carries the Overlapped cloud tier section (issue/commit split,
    staged agg_next slot, lagged-anchor + checkpoint semantics), the
    README matrix advertises the overlap column for exactly the
    oracle-validated methods, both docs name the CLI flag, and every
    documented mode exists in the schedule layer."""
    from repro.core.schedule import CLOUD_OVERLAP_MODES
    readme = (ROOT / "README.md").read_text()
    arch = (ROOT / "docs" / "architecture.md").read_text()
    assert "Overlapped cloud tier" in arch
    assert "Overlapped cloud tier" in readme
    for mode in CLOUD_OVERLAP_MODES:
        assert f"`{mode}`" in readme, f"README: cloud_overlap mode {mode}"
    assert "cloud_overlap" in arch and "--cloud_overlap" in readme
    assert "CloudSchedule" in arch and "CloudSchedule" in readme
    for text, name in ((readme, "README"), (arch, "architecture.md")):
        assert "agg_next" in text, name                # the staged slot
        assert "issue" in text and "commit" in text, name
        assert "replicated regime" in text, name       # fsdp rejection
    assert "w_inflight" in arch and "w_inflight" in readme  # oracle twin
    assert "edge_weights_agg" in arch    # issue-time weight pinning
    assert "one boundary earlier" in arch and "one boundary earlier" in \
        readme                           # the lag-1 commit rule
    assert "test_ref_fed_overlap.py" in readme
    assert "overlap_rows" in readme and "overlap_rows" in arch
    assert "max(round, RTT)" in readme and "max(round, RTT)" in arch
    # the README matrix overlap column matches the validated methods:
    # every sign method + hier_sgd run the overlap cells vs the oracle;
    # hier_local_qsgd is oracle-only (no distributed cell) -> not a ✓
    matrix = _readme_matrix()
    for method in hier.SIGN_METHODS + ("hier_sgd",):
        assert matrix[method].get("overlap") == "✓", (
            f"README matrix: {method} must advertise the overlap "
            f"schedule (tested by test_parity_matrix's overlap cells)")
    assert matrix["hier_local_qsgd"].get("overlap") == "—"


def test_readme_tier1_command():
    """The README's verify command matches ROADMAP's tier-1 gate."""
    readme = (ROOT / "README.md").read_text()
    assert "python -m pytest -x -q" in readme
