"""Composable layer blocks: pre-norm residual wrappers around the mixers.

Block protocol (single-replica view, engine handles P/D lifting):

    init(rng)             -> params for ONE layer
    apply(p, x, ctx)      -> (x, aux_loss, new_cache_slice)
    specs                 -> PartitionSpec tree mirroring init (leaf dims)
    cache_init(b, L, dt)  -> per-layer decode cache slice (or None)
    cache_specs(batch_ax, len_ax) -> spec tree for the cache slice
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import layers, moe as moe_mod, ssm
from repro.models.config import LMConfig

PyTree = Any


@dataclasses.dataclass
class Ctx:
    cfg: LMConfig
    mode: str = "train"               # train | prefill | decode
    positions: jax.Array | None = None  # [t] global positions
    pos: jax.Array | None = None        # scalar cache write offset
    enc_out: jax.Array | None = None    # whisper encoder output [b, f, d]
    shard_heads: Any = None             # callable pinning [.., h, hd] to
                                        # head-sharded TP layout (or None)
    shard_resid: Any = None             # callable pinning [.., t, d] to the
                                        # sequence-sharded residual layout
                                        # right after row-parallel
                                        # projections (AR -> RS rewrite)


@dataclasses.dataclass
class BlockDef:
    name: str
    init: Callable
    apply: Callable                    # (p, x, ctx, cache) -> (x, aux, cache')
    specs: PyTree
    cache_init: Callable | None = None
    cache_specs: Callable | None = None


def _with_norms(rng, d, inner: dict) -> dict:
    k1, k2 = jax.random.split(rng)
    return {"n1": layers.init_rms(k1, d), "n2": layers.init_rms(k2, d),
            **inner}


# ---------------------------------------------------------------------------
# Dense transformer block (attn + MLP), local/global attention flavours
# ---------------------------------------------------------------------------

def dense_block(cfg: LMConfig, model_shards: int, *, window: int = 0,
                theta: float | None = None, causal: bool = True,
                cross: bool = False, d_ff: int | None = None,
                name: str = "dense") -> BlockDef:
    th = theta if theta is not None else cfg.rope_theta
    ff = d_ff if d_ff is not None else cfg.d_ff

    def init(rng):
        ks = jax.random.split(rng, 4)
        p = {"n1": layers.init_rms(ks[0], cfg.d_model),
             "n2": layers.init_rms(ks[0], cfg.d_model),
             "attn": attn.init_gqa(ks[1], cfg),
             "mlp": layers.init_mlp(ks[2], cfg.d_model, ff, cfg.act)}
        if cross:
            p["nx"] = layers.init_rms(ks[0], cfg.d_model)
            p["xattn"] = attn.init_cross(ks[3], cfg)
        return p

    specs = {"n1": P(None), "n2": P(None),
             "attn": attn.gqa_specs(cfg, model_shards),
             "mlp": layers.mlp_specs(cfg.act)}
    if cross:
        specs["nx"] = P(None)
        specs["xattn"] = {k: attn.gqa_specs(cfg, model_shards)[k]
                          for k in ("wq", "wk", "wv", "wo")}

    def apply(p, x, ctx: Ctx, cache):
        prefill = ctx.mode == "prefill"
        h = layers.rms_norm(p["n1"], x, cfg.norm_eps)
        self_cache = cache.get("self") if cache is not None else None
        if causal:
            a, new_self = attn.gqa_attn(
                p["attn"], h, ctx.positions, cfg, theta=th, window=window,
                cache=self_cache, pos=ctx.pos, prefill=prefill,
                shard_heads=ctx.shard_heads)
        else:  # bidirectional encoder self-attention
            q = jnp.einsum("btd,dhk->bthk", h, p["attn"]["wq"])
            k = jnp.einsum("btd,dhk->bthk", h, p["attn"]["wk"])
            v = jnp.einsum("btd,dhk->bthk", h, p["attn"]["wv"])
            rep = cfg.n_heads // cfg.n_kv_heads
            o = attn._attend(q, attn._repeat_kv(k, rep),
                             attn._repeat_kv(v, rep), None)
            a = jnp.einsum("bthk,hkd->btd", o, p["attn"]["wo"])
            new_self = None
        if ctx.shard_resid is not None:
            a = ctx.shard_resid(a)
            x = ctx.shard_resid(x) + a
        else:
            x = x + a
        new_cache = {} if cache is not None else None
        if new_cache is not None and new_self is not None:
            new_cache["self"] = new_self
        if cross:
            hx = layers.rms_norm(p["nx"], x, cfg.norm_eps)
            if ctx.enc_out is not None:        # train/prefill: fresh enc kv
                ekv = attn.cross_kv(p["xattn"], ctx.enc_out, cfg)
            else:                               # decode: cached enc kv
                ekv = {"k": cache["ek"], "v": cache["ev"]}
            x = x + attn.cross_attn(p["xattn"], hx, ekv, cfg)
            if new_cache is not None:
                new_cache["ek"] = ekv["k"].astype(cache["ek"].dtype)
                new_cache["ev"] = ekv["v"].astype(cache["ev"].dtype)
        m_out = layers.mlp(p["mlp"], layers.rms_norm(p["n2"], x,
                                                     cfg.norm_eps), cfg.act)
        if ctx.shard_resid is not None:
            m_out = ctx.shard_resid(m_out)
        x = x + m_out
        return x, jnp.zeros((), jnp.float32), new_cache

    def cache_init(b, max_len, dtype=jnp.bfloat16):
        L = min(max_len, window) if window else max_len
        c = {"self": attn.gqa_cache_init(cfg, b, L, dtype)}
        if cross:
            c["ek"] = jnp.zeros((b, cfg.encoder_frames, cfg.n_kv_heads,
                                 cfg.hd), dtype)
            c["ev"] = jnp.zeros((b, cfg.encoder_frames, cfg.n_kv_heads,
                                 cfg.hd), dtype)
        return c

    def cache_specs(batch_ax, len_ax):
        la = None if window else len_ax
        c = {"self": attn.gqa_cache_specs(cfg, model_shards, batch_ax, la)}
        if cross:
            hks = attn._heads_spec(cfg.n_kv_heads, model_shards)
            c["ek"] = P(batch_ax, None, hks, None)
            c["ev"] = P(batch_ax, None, hks, None)
        return c

    return BlockDef(name, init, apply, specs, cache_init, cache_specs)


# ---------------------------------------------------------------------------
# MoE block (attn or MLA + MoE ffn)
# ---------------------------------------------------------------------------

def moe_block(cfg: LMConfig, model_shards: int, *, use_mla: bool = False,
              name: str = "moe") -> BlockDef:
    def init(rng):
        ks = jax.random.split(rng, 3)
        mix = (attn.init_mla(ks[1], cfg) if use_mla
               else attn.init_gqa(ks[1], cfg))
        return _with_norms(ks[0], cfg.d_model,
                           {"attn": mix, "moe": moe_mod.init_moe(ks[2], cfg)})

    specs = {"n1": P(None), "n2": P(None),
             "attn": (attn.mla_specs(cfg, model_shards) if use_mla
                      else attn.gqa_specs(cfg, model_shards)),
             "moe": moe_mod.moe_specs(cfg, model_shards)}

    def apply(p, x, ctx: Ctx, cache):
        prefill = ctx.mode == "prefill"
        h = layers.rms_norm(p["n1"], x, cfg.norm_eps)
        if use_mla:
            a, new_cache = attn.mla_attn(p["attn"], h, ctx.positions, cfg,
                                         cache=cache, pos=ctx.pos,
                                         prefill=prefill,
                                         shard_heads=ctx.shard_heads)
        else:
            a, new_cache = attn.gqa_attn(p["attn"], h, ctx.positions, cfg,
                                         theta=cfg.rope_theta, cache=cache,
                                         pos=ctx.pos, prefill=prefill,
                                         shard_heads=ctx.shard_heads)
        x = x + a
        h2 = layers.rms_norm(p["n2"], x, cfg.norm_eps)
        y, aux = moe_mod.moe_block(p["moe"], h2, cfg)
        return x + y, aux, new_cache

    def cache_init(b, max_len, dtype=jnp.bfloat16):
        return (attn.mla_cache_init(cfg, b, max_len, dtype) if use_mla
                else attn.gqa_cache_init(cfg, b, max_len, dtype))

    def cache_specs(batch_ax, len_ax):
        return (attn.mla_cache_specs(cfg, model_shards, batch_ax, len_ax)
                if use_mla
                else attn.gqa_cache_specs(cfg, model_shards, batch_ax,
                                          len_ax))

    return BlockDef(name, init, apply, specs, cache_init, cache_specs)


# ---------------------------------------------------------------------------
# Dense MLA block (deepseek first_dense layers + MTP block)
# ---------------------------------------------------------------------------

def mla_dense_block(cfg: LMConfig, model_shards: int, d_ff: int,
                    name: str = "dense") -> BlockDef:
    def init(rng):
        ks = jax.random.split(rng, 3)
        return _with_norms(ks[0], cfg.d_model,
                           {"attn": attn.init_mla(ks[1], cfg),
                            "mlp": layers.init_mlp(ks[2], cfg.d_model,
                                                   d_ff, cfg.act)})

    specs = {"n1": P(None), "n2": P(None),
             "attn": attn.mla_specs(cfg, model_shards),
             "mlp": layers.mlp_specs(cfg.act)}

    def apply(p, x, ctx: Ctx, cache):
        h = layers.rms_norm(p["n1"], x, cfg.norm_eps)
        a, new_cache = attn.mla_attn(p["attn"], h, ctx.positions, cfg,
                                     cache=cache, pos=ctx.pos,
                                     prefill=ctx.mode == "prefill",
                                     shard_heads=ctx.shard_heads)
        x = x + a
        x = x + layers.mlp(p["mlp"], layers.rms_norm(p["n2"], x,
                                                     cfg.norm_eps), cfg.act)
        return x, jnp.zeros((), jnp.float32), new_cache

    def cache_init(b, max_len, dtype=jnp.bfloat16):
        return attn.mla_cache_init(cfg, b, max_len, dtype)

    def cache_specs(batch_ax, len_ax):
        return attn.mla_cache_specs(cfg, model_shards, batch_ax, len_ax)

    return BlockDef(name, init, apply, specs, cache_init, cache_specs)


# ---------------------------------------------------------------------------
# SSM / recurrent blocks
# ---------------------------------------------------------------------------

def mamba_block(cfg: LMConfig, model_shards: int,
                name: str = "mamba") -> BlockDef:
    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"n1": layers.init_rms(k1, cfg.d_model),
                "mamba": ssm.init_mamba2(k2, cfg)}

    specs = {"n1": P(None), "mamba": ssm.mamba2_specs(cfg, model_shards)}

    def apply(p, x, ctx: Ctx, cache):
        h = layers.rms_norm(p["n1"], x, cfg.norm_eps)
        y, new_cache = ssm.mamba2_block(p["mamba"], h, cfg, state=cache)
        return x + y, jnp.zeros((), jnp.float32), new_cache

    def cache_init(b, max_len, dtype=jnp.float32):
        return ssm.mamba2_state_init(cfg, b, dtype)

    def cache_specs(batch_ax, len_ax):
        return ssm.mamba2_state_specs(cfg, model_shards, batch_ax)

    return BlockDef(name, init, apply, specs, cache_init, cache_specs)


def mlstm_block(cfg: LMConfig, model_shards: int,
                name: str = "mlstm") -> BlockDef:
    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"n1": layers.init_rms(k1, cfg.d_model),
                "mlstm": ssm.init_mlstm(k2, cfg)}

    specs = {"n1": P(None), "mlstm": ssm.mlstm_specs(cfg, model_shards)}

    def apply(p, x, ctx: Ctx, cache):
        h = layers.rms_norm(p["n1"], x, cfg.norm_eps)
        y, new_cache = ssm.mlstm_block(p["mlstm"], h, cfg, state=cache)
        return x + y, jnp.zeros((), jnp.float32), new_cache

    def cache_init(b, max_len, dtype=jnp.float32):
        return ssm.mlstm_state_init(cfg, b, dtype)

    def cache_specs(batch_ax, len_ax):
        return ssm.mlstm_state_specs(cfg, model_shards, batch_ax)

    return BlockDef(name, init, apply, specs, cache_init, cache_specs)


def slstm_block(cfg: LMConfig, model_shards: int,
                name: str = "slstm") -> BlockDef:
    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"n1": layers.init_rms(k1, cfg.d_model),
                "slstm": ssm.init_slstm(k2, cfg)}

    specs = {"n1": P(None), "slstm": ssm.slstm_specs(cfg, model_shards)}

    def apply(p, x, ctx: Ctx, cache):
        h = layers.rms_norm(p["n1"], x, cfg.norm_eps)
        y, new_cache = ssm.slstm_block(p["slstm"], h, cfg, state=cache)
        return x + y, jnp.zeros((), jnp.float32), new_cache

    def cache_init(b, max_len, dtype=jnp.float32):
        return ssm.slstm_state_init(cfg, b, dtype)

    def cache_specs(batch_ax, len_ax):
        return ssm.slstm_state_specs(cfg, model_shards, batch_ax)

    return BlockDef(name, init, apply, specs, cache_init, cache_specs)
