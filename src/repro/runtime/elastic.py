"""Elastic membership on the virtual-client vocabulary.

The paper's aggregation rules are natively elastic, and this module turns
that into runtime policy *in the same language the compiled step already
speaks*: ``core.hier``'s train step takes ``(edge_weights [P],
dev_weights [P, D], dev_mask)`` as runtime inputs, and with an active
``ClientConfig`` the mask may be client-granular (``[P, D, K]`` -- voter
``d*K + c`` of edge ``q``).  ``Membership`` tracks liveness at exactly
that granularity and emits exactly those arrays:

  * Cloud tier: ``w = sum_q (D_q/N) v_q`` -- a lost pod's weight is
    renormalized over the survivors (``edge_weights``); ``D_q`` is the
    LIVE data under edge ``q`` (physical slice sizes x the client
    ``|D_qk|`` shares of the ``ClientConfig``).
  * Edge tier: the weighted-popcount majority vote takes the membership
    mask as one more factor on the per-round participation mask; a dead
    or demoted client simply abstains (Theorem 3's MAP argument holds
    for the reduced quorum), and an edge whose whole quorum abstains
    leaves ``v_q`` unchanged -- the PR-5 empty-quorum / EF carry-forward
    contract, which the SCAFFOLD/MTGC/DC correction states follow too.
  * ``quorum`` decides whether an edge has enough live clients to
    contribute at all (a sub-quorum pod abstains wholesale).

Membership changes are value changes of fixed-shape arrays, so they are
**recompilation-free**: the jitted train step never retraces on churn
(pinned by ``tests/test_runtime_chaos.py``).

Fail-open invariant: if NO pod meets quorum, the emitted arrays keep
every voter counted (all-ones mask, uniform weights) -- real deployments
alert here but must never zero the model state.

Liveness comes from heartbeats (``heartbeat``/``sweep``), direct failure
marks (``mark_failed``/``restore``) and straggler demotion (``demote``)
-- simulated in tests by ``runtime.chaos`` fault injection.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

from repro.core.clients import ClientConfig


class MembershipArrays(NamedTuple):
    """The train step's membership inputs (plain float32 numpy arrays;
    fixed shapes, so feeding them to the compiled step never retraces).

    ``mask`` is client-granular ``[P, D, K]`` when the ``ClientConfig``
    is active (the virtual path multiplies it into the per-round
    participation mask), and the legacy ``[P, D]`` device mask
    otherwise."""
    edge_weights: np.ndarray     # [P]    D_q / N over the LIVE data
    dev_weights: np.ndarray      # [P, D] per-slice aggregation shares
    mask: np.ndarray             # [P, D, K] (active cc) or [P, D]


@dataclasses.dataclass
class Membership:
    pods: int
    devices_per_pod: int
    clients: ClientConfig = dataclasses.field(default_factory=ClientConfig)
    data_sizes: np.ndarray | None = None      # [P, D] slice sizes (None = equal)
    quorum: float = 0.5                       # min live-client fraction/edge
    heartbeat_timeout: float = 3.0

    def __post_init__(self):
        if self.data_sizes is None:
            self.data_sizes = np.ones((self.pods, self.devices_per_pod))
        self.data_sizes = np.asarray(self.data_sizes, np.float64)
        if self.data_sizes.shape != (self.pods, self.devices_per_pod):
            raise ValueError(
                f"data_sizes {self.data_sizes.shape} != "
                f"[pods, devices_per_pod] = "
                f"({self.pods}, {self.devices_per_pod})")
        k = self.clients.count
        shape = (self.pods, self.devices_per_pod, k)
        # per-client data sizes: physical slice size x |D_qk| share
        self.client_sizes = (
            self.data_sizes[:, :, None]
            * self.clients.weight_array(self.pods, self.devices_per_pod))
        self.live = np.ones(shape, bool)
        self.last_seen = np.zeros(shape)

    # -- liveness -----------------------------------------------------------
    def _idx(self, pod: int, dev: int | None, client: int | None):
        if dev is None:
            return np.s_[pod, :, :]
        if client is None:
            return np.s_[pod, dev, :]
        return np.s_[pod, dev, client]

    def heartbeat(self, pod: int, dev: int, now: float,
                  client: int | None = None):
        idx = self._idx(pod, dev, client)
        self.last_seen[idx] = now
        self.live[idx] = True

    def mark_failed(self, pod: int, dev: int | None = None,
                    client: int | None = None):
        """Kill a whole pod (dev=None), a device slice (client=None) or
        one virtual client."""
        self.live[self._idx(pod, dev, client)] = False

    # straggler escalation lands here: a demoted client is
    # indistinguishable from a sampled-out one (same abstention path,
    # pinned bitwise in tests/test_runtime_chaos.py)
    demote = mark_failed

    def restore(self, pod: int, dev: int | None = None,
                client: int | None = None, now: float | None = None):
        idx = self._idx(pod, dev, client)
        self.live[idx] = True
        if now is not None:
            self.last_seen[idx] = now

    def sweep(self, now: float):
        """Heartbeat-timeout sweep: silent clients lose their vote."""
        self.live &= (now - self.last_seen) <= self.heartbeat_timeout

    # -- weights ------------------------------------------------------------
    def pod_live(self) -> np.ndarray:
        """[P] -- a pod participates if its live-client fraction meets
        the vote quorum."""
        return self.live.mean(axis=(1, 2)) >= self.quorum

    def weights(self) -> MembershipArrays:
        """Emit the step's ``(edge_weights, dev_weights, mask)``.

        A failed client loses its vote AND its data share; a sub-quorum
        pod abstains wholesale (mask zeroed ONCE via ``pod_ok``, cloud
        weight zero).  Fail-open: if no pod meets quorum, every voter
        stays counted rather than zeroing the model state.
        """
        live = self.live.astype(np.float64)               # [P, D, K]
        pod_ok = self.pod_live().astype(np.float64)       # [P]
        if float((pod_ok * live.sum(axis=(1, 2))).sum()) == 0.0:
            live = np.ones_like(live)
            pod_ok = np.ones_like(pod_ok)
        mask3 = live * pod_ok[:, None, None]   # single pod_ok application
        sizes = self.client_sizes * mask3
        pod_sizes = sizes.sum(axis=(1, 2))
        edge_w = pod_sizes / max(pod_sizes.sum(), 1e-9)
        if self.clients.active:
            # client-granular mask; the |D_qk| shares already ride in
            # the step's vote weights / participating shares, so
            # dev_weights stays the STATIC physical-slice share (shares
            # renormalize per pod against the mask inside the step)
            dq = self.data_sizes.sum(axis=1, keepdims=True)
            dev_w = self.data_sizes / np.maximum(dq, 1e-9)
            mask = mask3
        else:
            # legacy [P, D] path: dev_weights ARE the aggregation
            # shares, renormalized over the live devices
            d_eff = sizes.sum(axis=2)                     # [P, D]
            dq = d_eff.sum(axis=1)
            dev_w = np.where(dq[:, None] > 0,
                             d_eff / np.maximum(dq[:, None], 1e-9), 0.0)
            mask = mask3[:, :, 0]
        return MembershipArrays(edge_w.astype(np.float32),
                                dev_w.astype(np.float32),
                                mask.astype(np.float32))

    # -- lifecycle ----------------------------------------------------------
    def fresh(self) -> "Membership":
        """A new all-live Membership with this one's configuration --
        the baseline for deterministic schedule replay
        (``runtime.chaos.compile_schedule`` / restore-and-replay)."""
        return Membership(self.pods, self.devices_per_pod,
                          clients=self.clients,
                          data_sizes=self.data_sizes.copy(),
                          quorum=self.quorum,
                          heartbeat_timeout=self.heartbeat_timeout)
