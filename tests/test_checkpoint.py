"""Checkpoint store: roundtrip, atomicity, corruption fallback, GC, async."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.checkpoint.async_ckpt import AsyncSaver


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 8)),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "step": jnp.asarray(seed, jnp.int32),
        "rng": jax.random.PRNGKey(seed + 1),
        "none_leaf": None,
    }


def test_roundtrip(tmp_path):
    t = _tree(3)
    store.save(tmp_path, 3, t)
    out = store.restore(tmp_path, 3, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bf16_and_key_roundtrip(tmp_path):
    t = _tree(1)
    store.save(tmp_path, 1, t)
    out = store.restore(tmp_path, 1, t)
    assert out["params"]["b"].dtype == jnp.bfloat16
    # keys usable after restore
    jax.random.normal(out["rng"], (2,))


def test_latest_and_gc(tmp_path):
    t = _tree(0)
    for s in [1, 2, 3, 4, 5]:
        store.save(tmp_path, s, t, keep=2)
    assert store.available_steps(tmp_path) == [4, 5]
    assert (tmp_path / "LATEST").read_text() == "5"


def test_corruption_falls_back(tmp_path):
    t = _tree(0)
    store.save(tmp_path, 1, t, keep=5)
    store.save(tmp_path, 2, t, keep=5)
    # corrupt the newest
    npz = tmp_path / "step_0000000002" / "arrays.npz"
    npz.write_bytes(b"garbage")
    got = store.restore_latest(tmp_path, t)
    assert got is not None and got[0] == 1


def test_restore_latest_none_when_empty(tmp_path):
    assert store.restore_latest(tmp_path / "nope", _tree()) is None


def test_async_saver(tmp_path):
    saver = AsyncSaver(tmp_path, keep=2)
    for s in [10, 20]:
        saver.submit(s, _tree(s))
    saver.close()
    assert store.available_steps(tmp_path) == [10, 20]
    out = store.restore(tmp_path, 20, _tree(20))
    assert int(out["step"]) == 20


def test_manifest_records_leaves(tmp_path):
    t = _tree(0)
    path = store.save(tmp_path, 7, t)
    manifest = json.loads((path / "manifest.json").read_text())
    assert manifest["step"] == 7
    assert any("params/w" in k for k in manifest["leaves"])
