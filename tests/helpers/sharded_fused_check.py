"""Multi-chip fused transport check: 2x2x2 (pod, data, model) host mesh.

The tentpole acceptance cell for the model-axis-sharded flat layout
(``core.flatbuf`` sharded layouts + the ``core.votes`` shard_map fused
program):

1. trajectory parity -- ``transport="fused"`` + ``state_layout="flat"``
   on the model=2 mesh is BITWISE identical to the ``ag_packed`` /
   tree-layout reference (the jnp oracle), on both the pure-jnp route
   and the per-rank Pallas kernel route (interpret mode on CPU);
2. the flat state actually engages the sharded layout
   (``layout.shards == 2``);
3. the optimized HLO of the compiled train step contains NO model-axis
   all-gather (no whole-leaf gather -- asserted STRICTLY via
   ``benchmarks.hlo_analysis.assert_axis_free``, so unattributed
   collectives fail the check instead of hiding in it), and its total
   all-gather traffic is bounded by the 1-bit packed uplink payload;
4. the UNEVEN TP leaf cell: an odd hidden dim (65 % 2 != 0) makes both
   weight matrices shard as padded blocks (``LeafSlot.shard_pad``) --
   the layout must stay ``shards == 2`` with ``shard_dim`` set (NO
   per-bucket copy), trajectories must stay bitwise vs the tree-state
   reference, and the optimized HLO must still carry zero model-axis
   all-gather bytes.

Run directly (forces 8 host devices before importing jax):
    PYTHONPATH=src python tests/helpers/sharded_fused_check.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import pathlib
import sys
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2]))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

import parity_harness as H  # noqa: E402
from benchmarks import hlo_analysis  # noqa: E402
from repro.core import hier  # noqa: E402
from repro.core.topology import Topology  # noqa: E402

Pn, Dn, Mn = 2, 2, 2
mesh = Mesh(np.array(jax.devices()).reshape(Pn, Dn, Mn),
            ("pod", "data", "model"))
topo = Topology(mesh=mesh, pod_axis="pod")
problem = H.make_problem(Pn, Dn)

# ---- 1a. bitwise trajectory parity, jnp route -------------------------
ref, _ = H.run_hier(topo, problem, "dc_hier_signsgd", "ag_packed", "tree")
got, _ = H.run_hier(topo, problem, "dc_hier_signsgd", "fused", "flat")
H.assert_trees_equal(ref, got, "multichip/fused/flat")
print("multichip fused/flat bitwise parity OK (jnp route)")

# ---- 1b. per-rank Pallas kernels inside shard_map (interpret on CPU) --
os.environ["REPRO_FUSED_PALLAS"] = "interpret"
small = H.make_problem(Pn, Dn, rounds=1, t_e=2)
ref_k, _ = H.run_hier(topo, small, "dc_hier_signsgd", "ag_packed", "tree")
got_k, _ = H.run_hier(topo, small, "dc_hier_signsgd", "fused", "flat")
H.assert_trees_equal(ref_k, got_k, "multichip/fused/flat/kernel")
del os.environ["REPRO_FUSED_PALLAS"]
print("multichip fused/flat bitwise parity OK (kernel route, interpret)")

# ---- 2 + 3. sharded layout engaged, HLO free of model-axis gathers ----
def _compiled_step_stats(prob, bundle):
    """(state, HLO stats) of the compiled fused/flat train step."""
    algo = H._algo("dc_hier_signsgd", "fused", "flat", t_e=prob["t_e"])
    init_fn, step = hier.make_hier_step(topo, algo, bundle)
    state = jax.jit(init_fn)(prob["w0"], jax.random.PRNGKey(1))
    ew = jnp.full((Pn,), 1.0 / Pn)
    dw = jnp.full((Pn, Dn), 1.0 / Dn)
    mask = jnp.ones((Pn, Dn))
    batch = {"train": {"x": prob["xs"][0], "y": prob["ys"][0]}}
    txt = jax.jit(step).lower(state, batch, ew, dw,
                              mask).compile().as_text()
    return state, hlo_analysis.analyze_hlo_text(
        txt, axis_sizes={"pod": Pn, "data": Dn, "model": Mn})


state, stats = _compiled_step_stats(problem, H.make_bundle())
layout = state.params.layout
assert layout.shards == Mn, layout
assert any(s.shard_dim is not None for s in layout.slots)

hlo_analysis.assert_axis_free(stats, op="all-gather", axis="model")
ag_total = hlo_analysis.collective_bytes(stats, op="all-gather")
payload_bound = 4 * layout.n_words        # the whole 1-bit uplink, uint32
assert 0 < ag_total <= payload_bound, (ag_total, payload_bound)
print(f"HLO: zero model-axis all-gather bytes; uplink all-gather "
      f"{ag_total:.0f} B <= packed payload bound {payload_bound} B")

# ---- 4. uneven TP leaves stay SHARDED as padded blocks ----------------
uneven = H.make_problem(Pn, Dn, hid=H.UNEVEN_HID)
ref_u, _ = H.run_hier(topo, uneven, "dc_hier_signsgd", "ag_packed",
                      "tree")
got_u, _ = H.run_hier(topo, uneven, "dc_hier_signsgd", "fused", "flat")
H.assert_trees_equal(ref_u, got_u, "multichip/fused/flat/uneven")
print("uneven TP leaf bitwise parity OK (jnp route)")

# the per-rank kernel route must sweep the uneven last block's zero
# shard tail under the don't-care contract (kernels/ops.py) -- rerun
# the cell through interpret-mode Pallas like the even cell above
os.environ["REPRO_FUSED_PALLAS"] = "interpret"
small_u = H.make_problem(Pn, Dn, rounds=1, t_e=2, hid=H.UNEVEN_HID)
ref_uk, _ = H.run_hier(topo, small_u, "dc_hier_signsgd", "ag_packed",
                       "tree")
got_uk, _ = H.run_hier(topo, small_u, "dc_hier_signsgd", "fused", "flat")
H.assert_trees_equal(ref_uk, got_uk, "multichip/fused/flat/uneven/kernel")
del os.environ["REPRO_FUSED_PALLAS"]
print("uneven TP leaf bitwise parity OK (kernel route, interpret)")

state_u, stats_u = _compiled_step_stats(uneven, H.make_bundle())
lay_u = state_u.params.layout
assert lay_u.shards == Mn, lay_u
padded = [s for s in lay_u.slots if s.shard_pad > 0]
assert len(padded) == 2, lay_u.slots      # w (65%2) and w2 (65%2)
assert all(s.shard_dim is not None for s in padded)
hlo_analysis.assert_axis_free(stats_u, op="all-gather", axis="model")
ag_u = hlo_analysis.collective_bytes(stats_u, op="all-gather")
assert 0 < ag_u <= 4 * lay_u.n_words, (ag_u, 4 * lay_u.n_words)
print(f"uneven HLO: zero model-axis all-gather bytes; uplink "
      f"{ag_u:.0f} B <= packed payload bound {4 * lay_u.n_words} B")
print("sharded fused check OK")
