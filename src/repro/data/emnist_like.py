"""Synthetic EMNIST-Digits-like classification task (paper Figs. 2-4).

Offline-deterministic replacement for the paper's datasets: a 10-class
Gaussian mixture in 784-d (class means on a scaled random simplex, shared
within-class covariance structure via random projections).  Heterogeneity
follows the paper exactly: for each class m a Dirichlet(alpha * 1_Q)
probability vector splits the class's samples across the Q edges
(alpha=0.1 -> the paper's extreme non-IID split); devices within an edge
are IID (paper Sec. V-A / Remark 3).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class FedDataCfg:
    n_classes: int = 10
    dim: int = 784
    n_train: int = 20000
    n_test: int = 4000
    q_edges: int = 4
    devices_per_edge: int = 5
    alpha: float = 0.1           # Dirichlet concentration (0.1 = paper)
    iid: bool = False
    seed: int = 0
    class_sep: float = 1.2
    noise_dim: int = 96          # intrinsic subspace dimensionality


def _make_task(cfg: FedDataCfg, rng: np.random.Generator):
    """Fixed class geometry (means + covariance projection) shared by every
    split -- train and test MUST come from the same mixture."""
    means = rng.normal(size=(cfg.n_classes, cfg.dim))
    means *= cfg.class_sep / np.linalg.norm(means, axis=1, keepdims=True)
    proj = (rng.normal(size=(cfg.noise_dim, cfg.dim))
            / np.sqrt(cfg.noise_dim))
    return means, proj


def _sample(cfg: FedDataCfg, means, proj, n: int,
            rng: np.random.Generator):
    y = rng.integers(0, cfg.n_classes, size=n)
    z = rng.normal(size=(n, cfg.noise_dim))
    x = means[y] + z @ proj + 0.3 * rng.normal(size=(n, cfg.dim))
    return x.astype(np.float32), y.astype(np.int32)


def make_federated_data(cfg: FedDataCfg):
    """Returns (device_data, test_set, edge_weights, device_weights).

    device_data[q][k] = {"x": ..., "y": ...} -- device k of edge q.
    edge_weights[q] = D_q / N;  device_weights[q][k] = |D_qk| / D_q.
    """
    rng = np.random.default_rng(cfg.seed)
    means, proj = _make_task(cfg, rng)
    x, y = _sample(cfg, means, proj, cfg.n_train, rng)
    xt, yt = _sample(cfg, means, proj, cfg.n_test, rng)

    # --- class -> edge assignment (paper: p_m ~ Dir(alpha 1_Q) per class)
    edge_idx: list[list[int]] = [[] for _ in range(cfg.q_edges)]
    for m in range(cfg.n_classes):
        idx = np.where(y == m)[0]
        rng.shuffle(idx)
        if cfg.iid:
            p = np.full(cfg.q_edges, 1.0 / cfg.q_edges)
        else:
            p = rng.dirichlet(np.full(cfg.q_edges, cfg.alpha))
        counts = np.floor(p * len(idx)).astype(int)
        counts[-1] = len(idx) - counts[:-1].sum()
        start = 0
        for q in range(cfg.q_edges):
            edge_idx[q].extend(idx[start:start + counts[q]])
            start += counts[q]

    device_data = []
    edge_sizes = []
    device_weights = []
    for q in range(cfg.q_edges):
        idx = np.array(edge_idx[q], dtype=int)
        rng.shuffle(idx)                        # devices IID within edge
        edge_sizes.append(len(idx))
        splits = np.array_split(idx, cfg.devices_per_edge)
        device_data.append(
            [{"x": x[s], "y": y[s]} for s in splits])
        dq = max(len(idx), 1)
        device_weights.append([len(s) / dq for s in splits])
    n = sum(edge_sizes)
    edge_weights = [s / n for s in edge_sizes]
    return device_data, {"x": xt, "y": yt}, edge_weights, device_weights


def device_batches(device_data, q, k, batch_size, rng: np.random.Generator):
    """One minibatch sampler for device (q, k) (with-replacement, paper's
    stochastic-gradient setting)."""
    d = device_data[q][k]
    n = len(d["y"])
    idx = rng.integers(0, n, size=min(batch_size, n)) if n else np.zeros(
        0, int)
    return {"x": d["x"][idx], "y": d["y"][idx]}
