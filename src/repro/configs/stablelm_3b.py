"""stablelm-3b [dense]: 32L d2560 32H (MHA) ff6912 v50304.
[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
import dataclasses

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
    vocab=50304, head_dim=80, rope_theta=1e4,
    param_mode="replicated", supports_long_context=False,
)

SMOKE = dataclasses.replace(
    CONFIG, name="stablelm-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
)
