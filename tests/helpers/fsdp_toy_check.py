"""Scratch: FSDP regime (fsdp_lift custom_vjp inside scan) vs replicated."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import hier
from repro.core.topology import Topology

Pn, Dn, Mn = 2, 2, 2
mesh = Mesh(np.array(jax.devices()).reshape(Pn, Dn, Mn),
            ("pod", "data", "model"))
topo = Topology(mesh=mesh, pod_axis="pod")

L, DIM = 3, 32

def layer_fn(lp, x):
    return jnp.tanh(x @ lp["w"] + lp["b"])

def loss_single(params, batch, rng):
    x = batch["x"]
    def body(x, lp):
        return layer_fn(lp, x), None
    x, _ = jax.lax.scan(body, x, params["layers"])
    pred = x @ params["head"]
    return jnp.mean((pred - batch["y"]) ** 2)

# fsdp master-loss: framework-style scan with lift per layer
compute_specs = {"layers": {"w": P(None, None, "model"), "b": P(None, "model")},
                 "head": P(None, "model")}
master_specs = {"layers": {"w": P(None, "data", "model"), "b": P(None, "model")},
                "head": P("data", "model")}

def loss_master(params, delta, batch, rngs, lift):
    # head lifted once; layers lifted inside scan
    head_dev = lift({"h": params["head"]}, {"h": delta["head"]},
                    {"h": P("data", "model")}, {"h": P(None, "model")})["h"]
    x = batch["x"]                           # [Pn, Dn, b, DIM]

    def body(x, sl):
        lp_master, ld_master = sl
        lp_dev = lift(lp_master, ld_master,
                      {"w": P("data", "model"), "b": P("model")},
                      {"w": P(None, "model"), "b": P("model")})
        x = jax.vmap(jax.vmap(layer_fn))(lp_dev, x)
        return x, None

    # move the leading L axis of each stacked leaf for scan
    x, _ = jax.lax.scan(
        body, x,
        (jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), params["layers"]),
         jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), delta["layers"])))
    pred = jnp.einsum("pdbi,pdio->pdbo", x, head_dev)
    losses = jnp.mean((pred - batch["y"]) ** 2, axis=(2, 3))  # [Pn, Dn] mean
    return jnp.sum(losses), losses

kw = jax.random.PRNGKey(0)
w0 = {"layers": {"w": 0.3 * jax.random.normal(kw, (L, DIM, DIM)),
                 "b": jnp.zeros((L, DIM))},
      "head": 0.3 * jax.random.normal(jax.random.PRNGKey(1), (DIM, DIM))}

T_E, STEPS, B = 2, 6, 8
xs = jax.random.normal(jax.random.PRNGKey(7), (STEPS, Pn, Dn, B, DIM))
wt = jax.random.normal(jax.random.PRNGKey(9), (Pn, DIM, DIM))
ys = jnp.einsum("spdbi,pio->spdbo", xs, wt)

results = {}
for mode in ["replicated", "fsdp"]:
    algo = hier.AlgoConfig(method="dc_hier_signsgd", mu=5e-3, t_e=T_E,
                           rho=1.0, transport="ag_packed",
                           compute_dtype=jnp.float32,
                           master_dtype=jnp.float32,
                           delta_dtype=jnp.float32)
    if mode == "replicated":
        # replicated master: mimic stacked-leaf specs with leading L dim None
        cs = {"layers": {"w": P(None, None, "model"), "b": P(None, "model")},
              "head": P(None, "model")}
        bundle = hier.ModelBundle(loss=loss_single, compute_specs=cs,
                                  master_specs=cs)
    else:
        ms = {"layers": {"w": P(None, "data", "model"),
                         "b": P(None, "model")},
              "head": P("data", "model")}
        bundle = hier.ModelBundle(loss=None, compute_specs=None,
                                  master_specs=ms, loss_master=loss_master,
                                  param_mode="fsdp")
    init_fn, step = hier.make_hier_step(topo, algo, bundle)
    state = init_fn(w0, jax.random.PRNGKey(1))
    ew = jnp.full((Pn,), 1.0 / Pn)
    dw = jnp.full((Pn, Dn), 1.0 / Dn)
    mask = jnp.ones((Pn, Dn))
    jstep = jax.jit(step)
    for s in range(STEPS):
        batch = {"train": {"x": xs[s], "y": ys[s]}}
        state, m = jstep(state, batch, ew, dw, mask)
    results[mode] = jax.tree.map(np.asarray, state.params)
    print(mode, "final loss", float(m["loss"]))

err = max(np.max(np.abs(a - b)) for a, b in
          zip(jax.tree.leaves(results["replicated"]),
              jax.tree.leaves(results["fsdp"])))
print("max |replicated - fsdp| =", err)
assert err < 1e-6
print("fsdp path OK")
