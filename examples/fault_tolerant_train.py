"""Fault-tolerance demo: checkpointed training that survives an injected
device failure (quorum vote) and a simulated crash (restore + replay).

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp

from repro import configs
from repro.core import hier
from repro.core.topology import single_device_topology
from repro.launch.train import RunCfg, run_training
from repro.runtime import failures

cfg = configs.get_smoke("stablelm_3b")
topo = single_device_topology()
algo = hier.AlgoConfig(method="dc_hier_signsgd", mu=2e-3, t_e=4, rho=0.3,
                       compute_dtype=jnp.float32)

with tempfile.TemporaryDirectory() as ckpt:
    run = RunCfg(steps=12, batch_per_device=4, seq_len=64,
                 ckpt_dir=ckpt, ckpt_every=4, log_every=4)
    # device (0,0) dies at step 6, recovers at step 9 (vote abstention
    # in between -- the paper's majority vote tolerates it natively)
    inj = failures.FaultInjector({6: ("device", 0, 0),
                                  9: ("recover", 0, 0)})
    state, hist = run_training(cfg, topo, algo, run, fault_injector=inj)
    print(f"\nphase 1 done at step {hist[-1]['step']} "
          f"(loss {hist[-1]['loss']:.3f}); simulating crash + restart...")
    # "crash": rerun with a longer horizon -- run_training resumes from
    # the newest intact checkpoint automatically
    run2 = RunCfg(steps=18, batch_per_device=4, seq_len=64,
                  ckpt_dir=ckpt, ckpt_every=4, log_every=4)
    state, hist2 = run_training(cfg, topo, algo, run2)
    assert hist2[0]["step"] >= 8, "should resume from a checkpoint"
    print(f"resumed at step {hist2[0]['step']}, finished at "
          f"{hist2[-1]['step']} (loss {hist2[-1]['loss']:.3f})")
print("OK")
