"""Unit + property tests for the sign-compression primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import signs


def test_sgn_zero_is_plus_one():
    assert int(signs.sgn(jnp.zeros(()))) == 1
    x = jnp.array([-2.0, -0.0, 0.0, 3.0])
    np.testing.assert_array_equal(np.asarray(signs.sgn(x)), [-1, 1, 1, 1])


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 1), min_size=1, max_size=200))
def test_pack_unpack_roundtrip(bits):
    s = jnp.asarray([1 if b else -1 for b in bits], jnp.int8)
    words = signs.pack_signs(s)
    out = signs.unpack_signs(words, len(bits))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(s))
    assert words.shape[-1] == signs.packed_size(len(bits))


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 9), st.integers(1, 70), st.integers(0, 2**31 - 1))
def test_vote_packed_equals_dense(k, n, seed):
    rng = np.random.default_rng(seed)
    s = rng.choice([-1, 1], size=(k, n)).astype(np.int8)
    dense = signs.majority_vote(jnp.asarray(s), axis=0)
    words = signs.pack_signs(jnp.asarray(s))
    packed = signs.majority_vote_packed(words, n)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(packed))


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 8), st.integers(1, 40), st.integers(0, 2**31 - 1))
def test_vote_mask_equals_subset(k, n, seed):
    """Masked vote == vote over the unmasked subset (abstention)."""
    rng = np.random.default_rng(seed)
    s = rng.choice([-1, 1], size=(k, n)).astype(np.int8)
    mask = rng.integers(0, 2, size=k).astype(np.int32)
    if mask.sum() == 0:
        mask[0] = 1
    v_mask = signs.majority_vote(jnp.asarray(s), jnp.asarray(mask)[:, None],
                                 axis=0)
    v_sub = signs.majority_vote(jnp.asarray(s[mask == 1]), axis=0)
    np.testing.assert_array_equal(np.asarray(v_mask), np.asarray(v_sub))


def test_vote_tie_positive():
    s = jnp.asarray([[1], [-1]], jnp.int8)
    assert int(signs.majority_vote(s, axis=0)[0]) == 1
    words = signs.pack_signs(s)
    assert int(signs.majority_vote_packed(words, 1)[0]) == 1


def test_ternary_unbiased_and_support():
    # small dim => keep probabilities (and the estimator SNR) high enough
    # that 256 draws pin the mean: per-coord std ~ norm*sqrt(p)/16.
    x = jax.random.normal(jax.random.PRNGKey(0), (256,))
    qs = jnp.stack([signs.ternary_quantize(x, jax.random.PRNGKey(i))
                    for i in range(256)])
    # unbiasedness: mean over draws approaches x
    err = jnp.abs(jnp.mean(qs, 0) - x).mean() / jnp.abs(x).mean()
    assert float(err) < 0.5
    # support: values are {0, +-||x||}
    norm = float(jnp.linalg.norm(x))
    vals = np.asarray(jnp.unique(jnp.abs(qs)))
    for v in vals:
        assert min(abs(v), abs(v - norm)) < 1e-3 * max(norm, 1.0), vals


def test_uplink_bits_table_ii():
    d, te = 1000, 15
    assert signs.uplink_bits("hier_sgd", d, te) == 32 * te * d
    assert signs.uplink_bits("hier_signsgd", d, te) == te * d
    assert signs.uplink_bits("dc_hier_signsgd", d, te) == te * d + 32 * d
    assert signs.uplink_bits("hier_local_qsgd", d, te) > te * d
    # the paper's headline: sign methods are ~32x cheaper than FP32
    assert signs.uplink_bits("hier_sgd", d, te) / signs.uplink_bits(
        "hier_signsgd", d, te) == 32


def test_uplink_bits_clients_consistent_with_cost_model():
    """ONE uplink accounting: signs.uplink_bits with (clients, rate) is
    the per-slice expectation, and the cost model's fleet pricing is
    exactly Q_EDGES*DEVS times it -- which in turn equals the legacy
    per-client formula scaled by the participating client count
    whenever Q*D*K*rate is integral."""
    from benchmarks import cost_model as cm
    d, te = cm.D_PARAMS, 15
    # legacy back-compat: clients=1 / full participation returns the
    # unscaled Table II int
    for m in ("hier_signsgd", "dc_hier_signsgd", "hier_sgd"):
        base = signs.uplink_bits(m, d, te)
        assert isinstance(base, int)
        assert signs.uplink_bits(m, d, te, clients=1,
                                 participation_rate=1.0) == base
        for k, p in ((64, 0.1), (4, 0.5), (1024, 0.25)):
            fleet = cm.Q_EDGES * cm.DEVS * signs.uplink_bits(
                m, d, te, clients=k, participation_rate=p)
            part = cm.participating_clients(k, p)
            assert fleet == pytest.approx(part * base)
