"""deepseek-v3-671b [moe]: 61L d7168 128H MLA, ff(expert)=2048 v129280,
MoE 1 shared + 256 routed top-8, 3 leading dense layers (ff 18432), MTP.
[arXiv:2412.19437; hf]
"""
import dataclasses

from repro.models.config import LMConfig, MLACfg, MoECfg

CONFIG = LMConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=2048,
    vocab=129280, head_dim=128, rope_theta=1e4,
    moe=MoECfg(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
               first_dense=3, dense_ff=18432, capacity_factor=1.25,
               group_tokens=1024),
    mla=MLACfg(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
               qk_rope_head_dim=64, v_head_dim=128),
    mtp=True,
    param_mode="fsdp", supports_long_context=False,
)

SMOKE = dataclasses.replace(
    CONFIG, name="deepseek-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=64, vocab=256, head_dim=16,
    moe=MoECfg(n_experts=8, top_k=2, d_expert=64, n_shared=1,
               first_dense=1, dense_ff=128, capacity_factor=1.5,
               group_tokens=32),
    mla=MLACfg(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
               qk_rope_head_dim=8, v_head_dim=16),
    param_mode="replicated",
)
