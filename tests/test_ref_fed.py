"""Behavioural tests of the paper's algorithms on the reference simulator.

These check the paper's *claims* at miniature scale:
  * Theorem 1/2: DC removes the heterogeneity floor -- under strong
    inter-edge skew, DC-HierSignSGD reaches lower loss than HierSignSGD;
  * Q=1 (single edge): delta == 0 and DC == plain exactly;
  * rho=0 == plain HierSignSGD exactly;
  * quorum masking: dropping a voter still converges.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ref_fed


def _quadratic_problem(q_edges=4, dim=24, hetero=2.0, seed=0, noise=0.05):
    """Per-edge quadratic losses with controllable gradient dissimilarity:
    f_q(w) = 0.5 ||w - (w* + hetero * u_q)||^2, sum_q u_q = 0."""
    rng = np.random.default_rng(seed)
    w_star = rng.normal(size=dim)
    u = rng.normal(size=(q_edges, dim))
    u -= u.mean(axis=0, keepdims=True)
    targets = jnp.asarray(w_star + hetero * u)

    def grad_fn_for(q):
        def grad_fn(params, batch, rng_):
            g = params["w"] - targets[q]
            if noise:
                g = g + jax.random.normal(rng_, g.shape) * noise
            return {"w": g}
        return grad_fn

    return w_star, targets, grad_fn_for


def _run(method, rho, hetero, rounds=25, t_e=5, q_edges=4, devs=2,
         mask=None, seed=0, noise=0.05):
    w_star, targets, grad_fn_for = _quadratic_problem(
        q_edges, hetero=hetero, seed=seed, noise=noise)
    cfg = ref_fed.HierConfig(mu=2e-2, t_e=t_e, rho=rho, method=method,
                             mu_sgd=0.2)
    state = ref_fed.init_state({"w": jnp.zeros(24)}, q_edges)
    ew = [1.0 / q_edges] * q_edges
    dw = [[1.0 / devs] * devs] * q_edges

    # dispatch per-edge grad fns through a single callable via batch tag
    def grad_fn(params, batch, rng_):
        return grad_fn_for(batch["q"])(params, batch, rng_)

    for t in range(rounds):
        batches = [[[{"q": q} for _ in range(t_e)] for _ in range(devs)]
                   for q in range(q_edges)]
        anchors = [[{"q": q} for _ in range(devs)] for q in range(q_edges)]
        state = ref_fed.global_round(
            state, cfg, grad_fn, batches, anchors, ew, dw,
            jax.random.PRNGKey(t), device_mask=mask)
    return float(jnp.linalg.norm(state.w["w"] - w_star))


def test_dc_removes_heterogeneity_floor():
    """The paper's core claim: 2*zeta floor killed by the correction."""
    err_plain = _run("hier_signsgd", 0.0, hetero=2.0)
    err_dc = _run("dc_hier_signsgd", 1.0, hetero=2.0)
    assert err_dc < 0.6 * err_plain, (err_plain, err_dc)


def test_dc_noop_when_homogeneous():
    """zeta = 0 -> correction changes little."""
    err_plain = _run("hier_signsgd", 0.0, hetero=0.0)
    err_dc = _run("dc_hier_signsgd", 1.0, hetero=0.0)
    assert abs(err_dc - err_plain) < 0.35 * max(err_plain, 0.1)


def test_rho_zero_equals_plain():
    # noise=0: the DC variant consumes extra anchor rng draws, so exact
    # trajectory equality is only defined for deterministic gradients
    e1 = _run("hier_signsgd", 0.0, hetero=1.0, rounds=6, seed=3, noise=0.0)
    e2 = _run("dc_hier_signsgd", 0.0, hetero=1.0, rounds=6, seed=3,
              noise=0.0)
    assert e1 == pytest.approx(e2, abs=1e-6)


def test_single_edge_dc_equals_plain():
    e1 = _run("hier_signsgd", 0.0, hetero=0.0, rounds=6, q_edges=1, seed=4,
              noise=0.0)
    e2 = _run("dc_hier_signsgd", 1.0, hetero=0.0, rounds=6, q_edges=1,
              seed=4, noise=0.0)
    assert e1 == pytest.approx(e2, abs=1e-6)


def test_quorum_mask_still_converges():
    mask = [[True, False], [True, True], [True, True], [False, True]]
    err = _run("dc_hier_signsgd", 1.0, hetero=2.0, mask=mask)
    assert err < 1.0


def test_baselines_converge():
    for method in ("hier_sgd", "hier_local_qsgd"):
        err = _run(method, 0.0, hetero=1.0)
        assert err < 1.5, method


def test_theory_bound_monotonicity():
    """C_dc (Thm 2) vs C (Thm 1): the zeta term shrinks with rho, the
    smoothness term grows -- exactly the paper's stability trade-off."""
    zeta, sigma, d, B, L, mu, te = 1.0, 0.1, 1e4, 400, 1.0, 5e-3, 15
    C = lambda: 2 * zeta + 2 * sigma * d / np.sqrt(B) + (1.5 * te - 1) * L * mu
    Cdc = lambda rho: (2 * (1 - rho) * zeta + 2 * sigma * d / np.sqrt(B)
                       + ((3 + 8 * rho) * te / 2 - 1) * L * mu)
    assert Cdc(0.0) == pytest.approx(C())
    rhos = np.linspace(0, 1, 11)
    zeta_terms = 2 * (1 - rhos) * zeta
    drift_terms = ((3 + 8 * rhos) * te / 2 - 1) * L * mu
    assert (np.diff(zeta_terms) < 0).all()
    assert (np.diff(drift_terms) > 0).all()
    # with significant heterogeneity full correction wins overall
    assert Cdc(1.0) < C()
