"""Reproduce the paper's experiments (Figs. 2-4) on the offline
EMNIST-like task: 4 methods under Dirichlet(0.1) inter-edge skew.

    PYTHONPATH=src python examples/paper_repro.py [--fast]

Prints the accuracy/loss tables that EXPERIMENTS.md quotes.
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks import paper_figs

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true")
args = ap.parse_args()

print("== Table II: uplink bits per global round ==")
for name, _, derived in paper_figs.table2_uplink_cost():
    print(f"  {name:34s} {derived}")

print("\n== Fig. 2: final test accuracy (8 rounds, T_E=15) ==")
for name, us, derived in paper_figs.fig2_accuracy(
        seeds=(0,) if args.fast else (0, 1)):
    print(f"  {name:34s} {derived}   ({us/1e6:.1f}s/round)")

print("\n== Fig. 4: rho sensitivity (non-IID, T_E=15) ==")
for name, _, derived in paper_figs.fig4_rho_sweep(
        rhos=(0.0, 0.2, 1.0) if args.fast else (0.0, 0.1, 0.2, 0.5, 1.0)):
    print(f"  {name:34s} {derived}")
print("\nExpected phenomenology (paper Sec. V): DC-HierSignSGD > "
      "HierSignSGD under non-IID; gap small under IID; rho>0 beats rho=0.")
