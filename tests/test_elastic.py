"""Elastic membership invariants (hypothesis property tests)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.runtime import elastic, failures


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 4), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_weights_invariants(pods, devs, seed):
    rng = np.random.default_rng(seed)
    m = elastic.Membership(pods, devs,
                           data_sizes=rng.integers(1, 100, (pods, devs)))
    # random failures, but keep at least one pod fully alive
    fail = rng.random((pods, devs)) < 0.4
    fail[rng.integers(pods)] = False
    for p, d in zip(*np.where(fail)):
        m.mark_failed(p, d)
    ew, dw, mask = m.weights()
    assert np.isclose(ew.sum(), 1.0)
    assert (ew >= 0).all() and (dw >= 0).all()
    # device weights renormalize within each live pod
    for q in range(pods):
        if ew[q] > 0:
            assert np.isclose(dw[q].sum(), 1.0)
    # masked devices carry no weight
    assert (dw[mask == 0] == 0).all()


def test_pod_loss_renormalizes():
    m = elastic.Membership(2, 4)
    m.mark_failed(0)                      # whole pod down
    ew, dw, mask = m.weights()
    assert ew[0] == 0.0 and np.isclose(ew[1], 1.0)
    assert (mask[0] == 0).all()


def test_quorum_gates_pod():
    m = elastic.Membership(1, 4, quorum=0.75)
    m.mark_failed(0, 0)
    m.mark_failed(0, 1)                   # 50% live < 75% quorum
    assert not m.pod_live()[0]


def test_heartbeat_sweep():
    m = elastic.Membership(1, 2, heartbeat_timeout=1.0)
    m.heartbeat(0, 0, now=10.0)
    m.heartbeat(0, 1, now=5.0)
    m.sweep(now=10.5)
    assert m.live[0, 0] and not m.live[0, 1]


def test_failure_detector_straggler():
    det = failures.FailureDetector(failures.FailurePolicy(
        straggler_factor=2.0, patience=2))
    for _ in range(10):
        det.record_step(1.0)
    assert not det.device_slow(0, 0, 1.1)
    assert not det.device_slow(0, 1, 5.0)   # first offence
    assert det.device_slow(0, 1, 5.0)       # second -> demote
    assert not det.device_slow(0, 1, 1.0) or True  # counter reset path


def test_failure_detector_loss():
    det = failures.FailureDetector()
    assert det.check_loss(1.0)
    assert not det.check_loss(float("nan"))
    assert not det.check_loss(float("inf"))
