"""The paper's EMNIST-Digits model: one-hidden-layer fully-connected net
(Sec. V-A), plus the pieces the reference simulator needs (grad_fn,
accuracy).  Used by the Fig. 2-4 reproduction benchmarks and the system
behaviour tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_mlp(rng, dim=784, hidden=64, classes=10):
    k1, k2 = jax.random.split(rng)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) / jnp.sqrt(dim),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, classes)) / jnp.sqrt(hidden),
        "b2": jnp.zeros((classes,)),
    }


def logits_fn(params, x):
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def loss_fn(params, batch):
    lg = logits_fn(params, batch["x"])
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


@jax.jit
def grad_fn(params, batch, rng):
    """ref_fed-compatible per-device stochastic gradient."""
    del rng
    return jax.grad(loss_fn)(params, {"x": jnp.asarray(batch["x"]),
                                      "y": jnp.asarray(batch["y"])})


@jax.jit
def accuracy(params, batch):
    lg = logits_fn(params, jnp.asarray(batch["x"]))
    return jnp.mean((jnp.argmax(lg, -1) == jnp.asarray(batch["y"]))
                    .astype(jnp.float32))


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
