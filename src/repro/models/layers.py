"""Basic neural blocks (pure jnp; single-replica view; TP via specs).

Every block follows the same protocol:

    init_<block>(rng, cfg, ...) -> params (pytree of f32 arrays)
    <block>(params, x, ...)     -> activations
    specs mirror init and carry the TP PartitionSpec of each leaf's *leaf*
    dims (the engine prepends pod/data dims as needed).

Sharding helpers return None-specs for dims that do not divide the model
axis, so small archs degrade to replicated compute instead of failing.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _norm_init(rng, shape):
    return jnp.ones(shape, jnp.float32)


def he_init(rng, shape, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(rng, shape, jnp.float32)
            * (1.0 / math.sqrt(fan_in)))


def rms_norm(g, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + g.astype(jnp.float32))).astype(x.dtype)


def init_rms(rng, d):
    return jnp.zeros((d,), jnp.float32)   # stored as (g - 1), gemma-style


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: [..., t, h, hd] (hd even); positions: [..., t] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # [...,t,1,half]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(rng, d, ff, act="swiglu"):
    ks = jax.random.split(rng, 3)
    p = {"up": he_init(ks[0], (d, ff)), "down": he_init(ks[1], (ff, d), ff)}
    if act == "swiglu":
        p["gate"] = he_init(ks[2], (d, ff))
    return p


def mlp_specs(act="swiglu"):
    s = {"up": P(None, "model"), "down": P("model", None)}
    if act == "swiglu":
        s["gate"] = P(None, "model")
    return s


def mlp(p, x, act="swiglu"):
    if act == "swiglu":
        h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    else:
        h = jax.nn.gelu(x @ p["up"])
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(rng, vocab, d):
    return {"table": jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02}


def embed_specs(vocab: int = 0, model_shards: int = 0):
    """Vocab-sharded when divisible; replicated otherwise (whisper's
    51865 does not divide the model axis)."""
    ok = model_shards and vocab and vocab % model_shards == 0
    return {"table": P("model" if ok else None, None)}


def embed(p, tokens, scale=False):
    x = jnp.take(p["table"], tokens, axis=0)
    if scale:
        x = x * math.sqrt(p["table"].shape[-1])
    return x


def unembed(table, x):
    """x: [..., d] -> logits [..., V] (vocab-sharded)."""
    return x @ table.T


def softmax_xent(logits, targets, mask=None):
    """Mean next-token cross-entropy; logits [..., t, V], targets [..., t].

    Vocab-parallel friendly: the gold logit is extracted with a one-hot
    reduction (local on each vocab shard + psum) instead of
    take_along_axis, which under GSPMD would all-gather the sharded
    logits (Megatron-style vocab-parallel xent).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = lse - gold
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.sum(nll * mask) / denom
    return jnp.mean(nll)
