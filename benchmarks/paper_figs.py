"""Paper reproductions: Table II + Figs. 2, 3, 4 (one function per table).

Each returns a list of CSV rows: (name, us_per_call, derived...).
``us_per_call`` is the measured wall time of one global round; ``derived``
carries the reproduction quantity (final accuracy / loss / bits).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.fed_runner import FedBenchCfg, run_fed
from repro.core import signs

METHODS = ["hier_sgd", "hier_local_qsgd", "hier_signsgd",
           "dc_hier_signsgd"]


def table2_uplink_cost(d: int = 51008, t_e: int = 15):
    """Table II: device->edge uplink bits per global round."""
    rows = []
    base = signs.uplink_bits("hier_sgd", d, t_e)
    for m in METHODS:
        bits = signs.uplink_bits(m, d, t_e)
        rows.append((f"table2/{m}", 0.0,
                     f"bits={bits} ratio_vs_fp32={base / bits:.1f}x"))
    return rows


def fig2_accuracy(seeds=(0, 1), rounds=8):
    """Fig. 2: test accuracy of the 4 methods, IID and non-IID."""
    rows = []
    for iid in (False, True):
        for m in METHODS:
            accs, wall = [], []
            for s in seeds:
                r = run_fed(FedBenchCfg(method=m, iid=iid, seed=s,
                                        rounds=rounds))
                accs.append(r["acc"][-1])
                wall.append(r["wall_s_per_round"])
            tag = "iid" if iid else "noniid"
            rows.append((f"fig2/{tag}/{m}", np.mean(wall) * 1e6,
                         f"final_acc={np.mean(accs):.4f}"))
    return rows


def fig3_te_sweep(te_values=(5, 15, 30), seeds=(0,), rounds=6):
    """Fig. 3: effect of T_E on training loss, DC (solid) vs plain."""
    rows = []
    for iid in (False, True):
        for te in te_values:
            for m in ("hier_signsgd", "dc_hier_signsgd"):
                finals, wall = [], []
                for s in seeds:
                    r = run_fed(FedBenchCfg(method=m, iid=iid, t_e=te,
                                            seed=s, rounds=rounds))
                    finals.append(r["loss"][-1])
                    wall.append(r["wall_s_per_round"])
                tag = "iid" if iid else "noniid"
                rows.append((f"fig3/{tag}/te{te}/{m}",
                             np.mean(wall) * 1e6,
                             f"final_loss={np.mean(finals):.4f}"))
    return rows


def fig4_rho_sweep(rhos=(0.0, 0.1, 0.2, 0.5, 1.0), seeds=(0,), rounds=8):
    """Fig. 4: sensitivity to the correction strength rho (T_E=15)."""
    rows = []
    for rho in rhos:
        finals, wall = [], []
        for s in seeds:
            r = run_fed(FedBenchCfg(method="dc_hier_signsgd", rho=rho,
                                    iid=False, t_e=15, seed=s,
                                    rounds=rounds))
            finals.append(r["loss"][-1])
            wall.append(r["wall_s_per_round"])
        rows.append((f"fig4/rho{rho}", np.mean(wall) * 1e6,
                     f"final_loss={np.mean(finals):.4f}"))
    return rows
