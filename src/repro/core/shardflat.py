"""shard_map plumbing for model-axis-sharded flat buffers.

A sharded :class:`~repro.core.flatbuf.FlatLayout` (``layout.shards >
1``) assigns each model (TP) shard one contiguous, tile-aligned bucket
of the flat coordinate space.  This module moves trees in and out of
that buffer **without any model-axis communication**: every operation
is a ``jax.experimental.shard_map`` program in which rank m runs the
ordinary ``flatbuf`` flatten/unflatten on its *local* leaf blocks with
``layout.bucket()`` -- no concatenate ever crosses a shard boundary, so
neither XLA's concat partitioner (which PR 2 had to dodge with
whole-leaf gathers, see the old ``gather_leafdims``) nor any implicit
all-gather is involved.

Spec conventions (derived from the layout, so in/out specs always agree
with the bucket geometry):

  * buffer  ``[P(, D), n_pad]``      -> ``P(pod(, data), model)``
  * sharded leaf                     -> model axis on ``slot.shard_dim``
  * per-bucket-copy leaf             -> replicated over model (each rank
    holds the identical copy; ``check_rep=False`` because shard_map
    cannot prove the replication invariant the layout guarantees)

Uneven sharded leaves (``slot.shard_pad > 0``) cross the shard_map
boundary in their PADDED shape -- shard_map requires every sharded dim
to divide the mesh axis, so trees are zero-extended via
``flatbuf.pad_tree`` on the way in and sliced back to the logical
extent via ``flatbuf.unpad_tree`` on the way out.  Both are
shard-boundary-aligned pad/slice ops (GSPMD's physical layout for an
unevenly sharded dim IS the ceil-padded form), so they lower without
model-axis communication; the zero tail is don't-care exactly like
tile padding.

``check_rep=False`` is safe here by construction: copies are only ever
written from model-replicated inputs through deterministic elementwise
programs, so they remain bit-identical on every rank.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import flatbuf
from repro.core.topology import Topology

PyTree = Any


def model_sharding(topo: Topology, specs: PyTree) -> flatbuf.ModelSharding:
    """The :class:`flatbuf.ModelSharding` of this mesh's model axis."""
    return flatbuf.ModelSharding(shards=topo.model_shards,
                                 axis=topo.model_axis, specs=specs)


def buf_spec(topo: Topology, layout: flatbuf.FlatLayout,
             batch_dims: int = 1) -> P:
    """PartitionSpec of a ``[*batch, n_pad]`` buffer of this layout."""
    ax = topo.model_axis if layout.shards > 1 else None
    lead = (topo.pod_axis, topo.data_axis)[:batch_dims]
    return P(*lead, ax)


def leaf_specs(topo: Topology, layout: flatbuf.FlatLayout,
               batch_dims: int = 1) -> PyTree:
    """Per-leaf PartitionSpecs implied by the layout's bucket placement.

    Sharded slots put the model axis on their ``shard_dim``; per-bucket
    copies are replicated over model.  Leading dims follow the usual
    ``[P(, D), *leaf]`` convention.
    """
    lead = (topo.pod_axis, topo.data_axis)[:batch_dims]
    out = []
    for slot in layout.slots:
        dims = [None] * len(slot.shape)
        if slot.shard_dim is not None:
            dims[slot.shard_dim] = topo.model_axis
        out.append(P(*lead, *dims))
    return layout.treedef.unflatten(out)


def _smap(topo: Topology, fn, in_specs, out_specs):
    return shard_map(fn, mesh=topo.mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def flatten(topo: Topology, layout: flatbuf.FlatLayout, tree: PyTree,
            batch_dims: int = 1, dtype: Any = None) -> jax.Array:
    """Sharded ``flatten_tree``: each rank writes only its own bucket.

    Bit-identical to the reference ``flatbuf.flatten_tree`` on the same
    sharded layout (same per-leaf casts, same placement), but lowers to
    purely local reshapes/concats -- zero collectives.
    """
    if layout.shards == 1:
        return flatbuf.flatten_tree(layout, tree, batch_dims=batch_dims,
                                    dtype=dtype)
    bucket = layout.bucket()

    def prog(local_tree):
        return flatbuf.flatten_tree(bucket, local_tree,
                                    batch_dims=batch_dims, dtype=dtype)

    tree = flatbuf.pad_tree(layout, tree, batch_dims)
    return _smap(topo, prog, (leaf_specs(topo, layout, batch_dims),),
                 buf_spec(topo, layout, batch_dims))(tree)


def tree_views(topo: Topology, fs: flatbuf.FlatState,
               cast: bool = True) -> PyTree:
    """Sharded ``FlatState.tree()``: leaf views without model gathers.

    Each rank slices its local bucket; sharded leaves come back with
    the model axis on ``shard_dim`` (== the master/compute placement
    the layout was built from), copies come back replicated.
    """
    layout, batch_dims = fs.layout, fs.batch_dims
    if layout.shards == 1:
        return fs.tree(cast=cast)
    bucket = layout.bucket()

    def prog(local_buf):
        return flatbuf.unflatten_tree(bucket, local_buf,
                                      batch_dims=batch_dims, cast=cast)

    out = _smap(topo, prog, (buf_spec(topo, layout, batch_dims),),
                leaf_specs(topo, layout, batch_dims))(fs.buf)
    return flatbuf.unpad_tree(layout, out, batch_dims)
