"""Synthetic EMNIST-Digits-like classification task (paper Figs. 2-4).

Offline-deterministic replacement for the paper's datasets: a 10-class
Gaussian mixture in 784-d (class means on a scaled random simplex, shared
within-class covariance structure via random projections).  Heterogeneity
is two-level:

  * **inter-edge** (the paper's setting): for each class m a
    Dirichlet(alpha * 1_Q) probability vector splits the class's samples
    across the Q edges (alpha=0.1 -> the paper's extreme non-IID split);
  * **intra-edge** (``alpha_client``): within each edge, a second
    Dirichlet(alpha_client * 1_K) draw per class splits the edge's
    samples across its devices, so devices under one edge server carry
    genuinely distinct class skews.  ``alpha_client=None`` (default) or
    ``inf`` keeps the legacy devices-IID-within-edge split BITWISE
    (paper Sec. V-A / Remark 3).

Both splits apportion integer sample counts by the largest-remainder
method (``data.cluster.largest_remainder``) -- proportional to the
Dirichlet draw with no rounding-residue bias on the last bucket.

``edge_assign`` selects how clients map to edges: ``fixed`` keeps the
generative grouping above, ``random`` scatters clients uniformly
(seeded), and ``clustered`` regroups them by label-histogram similarity
via the deterministic balanced clustering in ``data.cluster`` -- only
histograms cross the tier boundary, never samples.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data import cluster


@dataclasses.dataclass(frozen=True)
class FedDataCfg:
    n_classes: int = 10
    dim: int = 784
    n_train: int = 20000
    n_test: int = 4000
    q_edges: int = 4
    devices_per_edge: int = 5
    alpha: float = 0.1           # Dirichlet concentration (0.1 = paper)
    iid: bool = False
    seed: int = 0
    class_sep: float = 1.2
    noise_dim: int = 96          # intrinsic subspace dimensionality
    alpha_client: float | None = None  # intra-edge Dirichlet concentration
                                 # (per-class skew ACROSS the edge's
                                 # devices); None or inf = legacy
                                 # devices-IID split, bitwise
    edge_assign: str = "fixed"   # fixed | random | clustered (see
                                 # data.cluster); fixed = generative
                                 # grouping, bitwise legacy


def _make_task(cfg: FedDataCfg, rng: np.random.Generator):
    """Fixed class geometry (means + covariance projection) shared by every
    split -- train and test MUST come from the same mixture."""
    means = rng.normal(size=(cfg.n_classes, cfg.dim))
    means *= cfg.class_sep / np.linalg.norm(means, axis=1, keepdims=True)
    proj = (rng.normal(size=(cfg.noise_dim, cfg.dim))
            / np.sqrt(cfg.noise_dim))
    return means, proj


def _sample(cfg: FedDataCfg, means, proj, n: int,
            rng: np.random.Generator):
    y = rng.integers(0, cfg.n_classes, size=n)
    z = rng.normal(size=(n, cfg.noise_dim))
    x = means[y] + z @ proj + 0.3 * rng.normal(size=(n, cfg.dim))
    return x.astype(np.float32), y.astype(np.int32)


def make_federated_data(cfg: FedDataCfg):
    """Returns (device_data, test_set, edge_weights, device_weights).

    device_data[q][k] = {"x": ..., "y": ...} -- device k of edge q.
    edge_weights[q] = D_q / N;  device_weights[q][k] = |D_qk| / D_q.
    """
    if cfg.edge_assign not in cluster.EDGE_ASSIGN_MODES:
        raise ValueError(
            f"unknown edge_assign {cfg.edge_assign!r}; expected one of "
            f"{cluster.EDGE_ASSIGN_MODES}")
    if cfg.alpha_client is not None and cfg.alpha_client <= 0:
        raise ValueError(
            f"alpha_client must be positive (or None): {cfg.alpha_client}")
    rng = np.random.default_rng(cfg.seed)
    means, proj = _make_task(cfg, rng)
    x, y = _sample(cfg, means, proj, cfg.n_train, rng)
    xt, yt = _sample(cfg, means, proj, cfg.n_test, rng)

    # --- class -> edge assignment (paper: p_m ~ Dir(alpha 1_Q) per
    # class), apportioned by largest remainder (floor residue used to
    # land entirely on the last edge, biasing its size under small
    # alpha)
    edge_cls: list[list[np.ndarray]] = [[] for _ in range(cfg.q_edges)]
    for m in range(cfg.n_classes):
        idx = np.where(y == m)[0]
        rng.shuffle(idx)
        if cfg.iid:
            p = np.full(cfg.q_edges, 1.0 / cfg.q_edges)
        else:
            p = rng.dirichlet(np.full(cfg.q_edges, cfg.alpha))
        counts = cluster.largest_remainder(p, len(idx))
        start = 0
        for q in range(cfg.q_edges):
            edge_cls[q].append(idx[start:start + counts[q]])
            start += counts[q]

    client_iid = (cfg.alpha_client is None
                  or not np.isfinite(cfg.alpha_client))
    device_data = []
    for q in range(cfg.q_edges):
        if client_iid:
            idx = np.concatenate(edge_cls[q])
            rng.shuffle(idx)                    # devices IID within edge
            splits = np.array_split(idx, cfg.devices_per_edge)
        else:
            # intra-edge skew: per class present in the edge, a second
            # Dirichlet draw splits that class across the edge's devices
            per_dev: list[list[np.ndarray]] = [
                [] for _ in range(cfg.devices_per_edge)]
            for cls in edge_cls[q]:
                if not len(cls):
                    continue
                pk = rng.dirichlet(
                    np.full(cfg.devices_per_edge, cfg.alpha_client))
                ck = cluster.largest_remainder(pk, len(cls))
                start = 0
                for k in range(cfg.devices_per_edge):
                    per_dev[k].append(cls[start:start + ck[k]])
                    start += ck[k]
            splits = []
            for k in range(cfg.devices_per_edge):
                s = (np.concatenate(per_dev[k]) if per_dev[k]
                     else np.zeros(0, int))
                rng.shuffle(s)
                splits.append(s)
        device_data.append([{"x": x[s], "y": y[s]} for s in splits])

    if cfg.edge_assign != "fixed":
        # server-side regrouping: permute clients across edges keeping
        # devices_per_edge slots per edge.  Only label HISTOGRAMS feed
        # the clustered mode -- raw (x, y) rows stay on the client.
        flat = [d for edge in device_data for d in edge]
        if cfg.edge_assign == "random":
            assign = cluster.random_assignment(len(flat), cfg.q_edges,
                                               cfg.seed)
        else:
            sigs = cluster.label_histogram_signatures(device_data,
                                                      cfg.n_classes)
            assign = cluster.cluster_edges(sigs, cfg.q_edges)
        order = cluster.assignment_order(assign, cfg.q_edges)
        device_data = [
            [flat[i] for i in order[q * cfg.devices_per_edge:
                                    (q + 1) * cfg.devices_per_edge]]
            for q in range(cfg.q_edges)]

    edge_sizes, device_weights = [], []
    for edge in device_data:
        dq = sum(len(d["y"]) for d in edge)
        edge_sizes.append(dq)
        device_weights.append([len(d["y"]) / max(dq, 1) for d in edge])
    n = sum(edge_sizes)
    edge_weights = [s / n for s in edge_sizes]
    return device_data, {"x": xt, "y": yt}, edge_weights, device_weights


def device_batches(device_data, q, k, batch_size, rng: np.random.Generator):
    """One minibatch sampler for device (q, k) (with-replacement, paper's
    stochastic-gradient setting)."""
    d = device_data[q][k]
    n = len(d["y"])
    idx = rng.integers(0, n, size=min(batch_size, n)) if n else np.zeros(
        0, int)
    return {"x": d["x"][idx], "y": d["y"][idx]}
