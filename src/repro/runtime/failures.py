"""Failure detection + recovery policy for the training driver.

Detection signals:
  * non-finite loss (desync / data corruption / numeric blow-up),
  * step-time outliers (straggler escalation: after ``patience``
    consecutive slow steps a client is demoted to abstention via the
    membership mask; the paper's majority vote makes this loss-free),
  * injected faults (``runtime.chaos`` -- deterministic seeded
    schedules for tests / chaos engineering).

Recovery: restore the newest intact checkpoint and replay.  Because the
data pipeline is cursor-addressable (batch = f(seed, step)) and the
membership arrays replay from the chaos schedule, replay is
deterministic (pinned bitwise in the parity matrix's
kill-restore-replay cell).

``may_restore()`` is a PURE query of the restore budget; the driver
calls ``record_restore()`` only when a restore actually happens.
"""
from __future__ import annotations

import collections
import dataclasses
import math


@dataclasses.dataclass
class FailurePolicy:
    straggler_factor: float = 3.0    # x median step time
    patience: int = 3
    max_restores: int = 5
    window: int = 256                # step-time history length


class FailureDetector:
    def __init__(self, policy: FailurePolicy | None = None):
        self.policy = policy or FailurePolicy()
        # bounded deque: appends evict the oldest entry in O(1) (the
        # old list.pop(0) window was O(n) per step)
        self.step_times: collections.deque[float] = collections.deque(
            maxlen=self.policy.window)
        self.slow_counts: dict[tuple, int] = {}
        self.restores = 0

    def check_loss(self, loss: float) -> bool:
        """True -> healthy; False -> restore required."""
        return math.isfinite(loss)

    def record_step(self, dt: float):
        self.step_times.append(dt)

    def median_step(self) -> float:
        if not self.step_times:
            return 0.0
        s = sorted(self.step_times)
        return s[len(s) // 2]

    def device_slow(self, pod: int, dev: int, dt: float,
                    client: int | None = None) -> bool:
        """Per-client straggler accounting; True -> demote to abstention
        (``Membership.demote`` -- the demoted client is then
        indistinguishable from a sampled-out one)."""
        med = self.median_step()
        key = (pod, dev, client)
        if med and dt > self.policy.straggler_factor * med:
            self.slow_counts[key] = self.slow_counts.get(key, 0) + 1
        else:
            self.slow_counts[key] = 0
        return self.slow_counts[key] >= self.policy.patience

    def may_restore(self) -> bool:
        """Pure budget query: would one more restore stay within
        ``max_restores``?  Does NOT consume budget -- call
        :meth:`record_restore` when the restore actually happens."""
        return self.restores < self.policy.max_restores

    def record_restore(self):
        """Consume one unit of restore budget (an actual restore ran)."""
        self.restores += 1


# The chaos engine superseded the old dict-schedule FaultInjector that
# lived here; the name stays importable for existing drivers/tests (the
# legacy ``{step: (kind, pod, dev)}`` schedule form still works).
from repro.runtime.chaos import FaultInjector  # noqa: E402,F401
