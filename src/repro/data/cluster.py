"""Cluster-aware edge assignment from client data signatures (FLT-style).

The paper's bias term is *inter-cluster* drift, so WHERE a client is
attached matters as much as what correction runs: regrouping clients
into edges by data similarity attacks the same heterogeneity the DC /
SCAFFOLD / MTGC corrections cancel algorithmically.  This module is the
server-side half of that scenario axis:

  * **signatures** -- the only per-client statistic that crosses the
    device->server tier boundary: a normalized label histogram
    (classification) or an aggregate mean-embedding / unigram sketch
    (LM streams).  Raw samples, features and tokens NEVER leave the
    client (property-tested in ``tests/test_data_hetero.py``).
  * **balanced deterministic clustering** -- ``cluster_edges`` groups
    the signatures into ``n_edges`` clusters of exactly
    ``n_clients / n_edges`` members (edges have fixed fan-in: every
    physical slot must be occupied), via average-linkage agglomerative
    merging followed by a capacity-constrained greedy transport onto
    the cluster centroids.

Determinism contract (mirrors the splitmix32 participation scheme of
``core.clients``: reproducible across process restarts, partitioning
and client arrival order): the assignment is a pure function of the
signature MULTISET -- clients are canonically ordered by lexicographic
signature sort before any distance is computed, cluster labels are
fixed by each cluster's lexicographically-leading member, and every
tie breaks by canonical rank.  No RNG is consumed at all, so the same
fleet re-clustered on any server, any seed, in any client order lands
in the same edges.
"""
from __future__ import annotations

import numpy as np

EDGE_ASSIGN_MODES = ("fixed", "random", "clustered")


def largest_remainder(p, n: int) -> np.ndarray:
    """Apportion ``n`` items proportionally to ``p`` (largest-remainder
    method): ``floor(p*n)`` each, then the leftover items go to the
    largest fractional remainders (ties break by index).  Replaces the
    floor-based split ``counts[-1] = n - counts[:-1].sum()`` that dumped
    ALL rounding residue on the last bucket (under small Dirichlet
    alpha the residue is almost one item per bucket, a systematic size
    bias).  Always returns nonnegative ints summing exactly to ``n``."""
    p = np.asarray(p, np.float64)
    if p.ndim != 1 or len(p) == 0 or np.any(p < 0):
        raise ValueError(f"proportions must be a nonnegative vector: {p!r}")
    tot = p.sum()
    quota = (p / tot) * n if tot > 0 else np.full(len(p), n / len(p))
    counts = np.floor(quota).astype(int)
    rem = int(n - counts.sum())
    if rem > 0:
        frac = quota - counts
        counts[np.argsort(-frac, kind="stable")[:rem]] += 1
    return counts


def label_histogram_signatures(device_data, n_classes: int) -> np.ndarray:
    """[n_clients, C] row-normalized label histograms, edge-major
    ``(q, k)`` client order.  The histogram is the ONLY thing computed
    from the client's data -- no feature rows are touched."""
    sigs = []
    for edge in device_data:
        for d in edge:
            h = np.bincount(np.asarray(d["y"]).astype(int).ravel(),
                            minlength=n_classes).astype(np.float64)
            sigs.append(h / max(h.sum(), 1.0))
    return np.stack(sigs)


def sketch_signatures(vectors) -> np.ndarray:
    """[n_clients, F] mean-embedding / unigram sketches, L2-normalized
    per client.  Callers pass ALREADY-AGGREGATED per-client vectors (a
    mean embedding, a unigram distribution): the per-row reduction
    happens client-side, so only the F-dim aggregate crosses tiers."""
    v = np.asarray(vectors, np.float64)
    if v.ndim != 2:
        raise ValueError(f"sketches must be [n_clients, F]: {v.shape}")
    return v / np.maximum(np.linalg.norm(v, axis=1, keepdims=True), 1e-12)


def _avg_linkage(s: np.ndarray, n_edges: int) -> list[list[int]]:
    """Average-linkage agglomerative merge of the canonically-sorted
    signatures ``s`` down to ``n_edges`` clusters (squared-L2 linkage;
    ties keep the earliest pair in canonical order)."""
    d2 = np.sum((s[:, None, :] - s[None, :, :]) ** 2, axis=-1)
    clusters = [[i] for i in range(len(s))]
    while len(clusters) > n_edges:
        best = None
        for i in range(len(clusters)):
            for j in range(i + 1, len(clusters)):
                link = float(np.mean(d2[np.ix_(clusters[i], clusters[j])]))
                if best is None or link < best[0] - 1e-12:
                    best = (link, i, j)
        _, i, j = best
        clusters[i] = clusters[i] + clusters[j]
        del clusters[j]
    return clusters


def cluster_edges(signatures, n_edges: int,
                  capacity: int | None = None) -> np.ndarray:
    """Group clients into ``n_edges`` equal-size edges by signature
    similarity.  Returns ``assignment[i]`` = edge id of original client
    ``i`` with exactly ``capacity`` (= n/n_edges) members per edge.

    Deterministic and invariant to the clients' arrival order: the
    partition (and the edge LABELS, pinned to each cluster's
    lexicographically-leading signature) depends only on the signature
    multiset -- see the module docstring for the full contract."""
    sig = np.asarray(signatures, np.float64)
    n = len(sig)
    if n_edges < 1 or n % n_edges:
        raise ValueError(
            f"{n} clients do not fill {n_edges} equal edges")
    cap = n // n_edges
    if capacity is not None and capacity != cap:
        raise ValueError(
            f"capacity {capacity} != {n} clients / {n_edges} edges")
    order = np.lexsort(sig.T[::-1])        # canonical client order
    s = sig[order]
    clusters = _avg_linkage(s, n_edges)
    clusters.sort(key=min)                 # stable edge labels
    cents = np.stack([s[c].mean(axis=0) for c in clusters])
    # capacity-constrained greedy transport onto the centroids: claim
    # (client, edge) pairs by ascending distance; full edges and placed
    # clients drop out.  Ties break by (canonical rank, edge id).
    d2 = np.sum((s[:, None, :] - cents[None, :, :]) ** 2, axis=-1)
    placed = np.full(n, -1, int)
    load = np.zeros(n_edges, int)
    for _, i, e in sorted((float(d2[i, e]), i, e)
                          for i in range(n) for e in range(n_edges)):
        if placed[i] < 0 and load[e] < cap:
            placed[i] = e
            load[e] += 1
    assignment = np.empty(n, int)
    assignment[order] = placed
    return assignment


def assignment_order(assignment, n_edges: int) -> np.ndarray:
    """Flatten an assignment into slot order: ``out[q*cap + j]`` = the
    original (edge-major) client index occupying slot ``j`` of new edge
    ``q`` (members keep ascending original order within an edge).  This
    is the permutation ``core.clients.regroup_clients`` and
    ``ref_fed.regroup_client_data`` consume."""
    a = np.asarray(assignment, int)
    cap = len(a) // n_edges
    slots = [np.flatnonzero(a == q) for q in range(n_edges)]
    if any(len(s) != cap for s in slots):
        raise ValueError(
            f"assignment is not balanced to {cap} clients/edge: "
            f"{[len(s) for s in slots]}")
    return np.concatenate(slots)


def random_assignment(n_clients: int, n_edges: int,
                      seed: int = 0) -> np.ndarray:
    """Seeded uniform client->edge scatter (the 'random' baseline of the
    bias study: every edge sees an exchangeable mix, so inter-edge drift
    collapses while intra-edge heterogeneity is maximal).  Balanced to
    capacity; deterministic in ``seed`` only."""
    if n_clients % n_edges:
        raise ValueError(
            f"{n_clients} clients do not fill {n_edges} equal edges")
    rng = np.random.default_rng((seed, 0x51C))
    perm = rng.permutation(n_clients)
    assignment = np.empty(n_clients, int)
    assignment[perm] = np.arange(n_clients) // (n_clients // n_edges)
    return assignment
