"""Serve a reduced model: batched prefill + autoregressive decode with the
framework's KV-cache serving path (same code the decode_32k/long_500k
dry-run cells lower).

    PYTHONPATH=src python examples/serve_decode.py
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.topology import single_device_topology
from repro.models import build

cfg = configs.get_smoke("zamba2_2p7b")      # hybrid SSM: O(1) decode state
topo = single_device_topology()
built = build.build_model(cfg, topo)
params = built.init_params(jax.random.PRNGKey(0))

B, PROMPT, GEN = 4, 24, 16
prompts = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0,
                             cfg.vocab, jnp.int32)

logits, cache = built.prefill(params, {"tokens": prompts},
                              max_len=PROMPT + GEN)
tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
decode = jax.jit(built.decode_step)
out = [tok]
t0 = time.time()
for _ in range(GEN - 1):
    logits, cache = decode(params, cache, tok)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out.append(tok)
dt = time.time() - t0
gen = jnp.concatenate(out, axis=1)
print(f"prompts {prompts.shape} -> generated {gen.shape}")
print(f"decode: {(GEN-1)*B/dt:.1f} tok/s (batch {B}, CPU, reduced config)")
print("sample token ids:", gen[0][:10].tolist())
assert bool(jnp.isfinite(logits).all())
print("OK")
