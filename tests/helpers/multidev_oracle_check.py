"""Scratch: 8-host-device equivalence of distributed hier vs ref_fed oracle."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import hier, ref_fed
from repro.core.topology import Topology

Pn, Dn, Mn = 2, 2, 2
mesh = Mesh(np.array(jax.devices()).reshape(Pn, Dn, Mn),
            ("pod", "data", "model"))
topo = Topology(mesh=mesh, pod_axis="pod")

# model: small linear-regression (deterministic loss; rng unused)
def loss_fn(params, batch, rng):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)

kw = jax.random.PRNGKey(0)
w0 = {"w": jax.random.normal(kw, (16, 64)) * 0.3,
      "b": jnp.zeros((64,))}
specs = {"w": P(None, "model"), "b": P("model")}

T_E, ROUNDS = 3, 3
B = 8
# per-(pod, device, step) batches with heterogeneity across pods
rb = jax.random.PRNGKey(7)
xs = jax.random.normal(rb, (ROUNDS * T_E, Pn, Dn, B, 16))
w_true = jax.random.normal(jax.random.PRNGKey(9), (Pn, 16, 64))  # per-pod target!
ys = jnp.einsum("spdbi,pio->spdbo", xs, w_true)

for method in ["hier_signsgd", "dc_hier_signsgd", "hier_sgd"]:
    for transport in (["ag_packed", "ar_int8"] if "sign" in method else ["ag_packed"]):
        algo = hier.AlgoConfig(method=method, mu=5e-3, mu_sgd=0.05, t_e=T_E,
                               rho=1.0, transport=transport,
                               compute_dtype=jnp.float32,
                               master_dtype=jnp.float32,
                               delta_dtype=jnp.float32)
        bundle = hier.ModelBundle(loss=loss_fn, compute_specs=specs,
                                  master_specs=specs)
        init_fn, step = hier.make_hier_step(topo, algo, bundle)
        state = init_fn(w0, jax.random.PRNGKey(1))
        ew = jnp.full((Pn,), 1.0 / Pn)
        dw = jnp.full((Pn, Dn), 1.0 / Dn)
        mask = jnp.ones((Pn, Dn))
        jstep = jax.jit(step)
        for s in range(ROUNDS * T_E):
            batch = {"train": {"x": xs[s], "y": ys[s]},
                     "anchor": {"x": xs[s - s % T_E], "y": ys[s - s % T_E]}}
            state, m = jstep(state, batch, ew, dw, mask)
        w_dist = np.asarray(state.params["w"][0])  # pod 0 edge model

        # ---- oracle (ref_fed): same trajectory
        cfg = ref_fed.HierConfig(mu=5e-3, mu_sgd=0.05, t_e=T_E, rho=1.0,
                                 method=method)
        fstate = ref_fed.init_state(w0, Pn)
        grad_fn = lambda p, b, r: jax.grad(loss_fn)(p, b, r)
        for t in range(ROUNDS):
            batches = [[[{"x": xs[t * T_E + tau, q, k],
                          "y": ys[t * T_E + tau, q, k]}
                         for tau in range(T_E)] for k in range(Dn)]
                       for q in range(Pn)]
            anchors = [[{"x": xs[t * T_E, q, k], "y": ys[t * T_E, q, k]}
                        for k in range(Dn)] for q in range(Pn)]
            fstate = ref_fed.global_round(
                fstate, cfg, grad_fn, batches, anchors,
                [1.0 / Pn] * Pn, [[1.0 / Dn] * Dn] * Pn,
                jax.random.PRNGKey(1))
        # oracle state.w is the cloud agg; distributed pod-0 edge model at
        # step ROUNDS*T_E has NOT yet been cloud-aggregated (prologue of the
        # next step does it) -> aggregate manually for comparison.
        vq = np.asarray(state.params["w"])
        w_dist_agg = (vq * np.asarray(ew)[:, None, None]).sum(0)
        w_ref = np.asarray(fstate.w["w"])
        err = np.max(np.abs(w_dist_agg - w_ref))
        print(f"{method:16s}/{transport:10s} max|w_dist - w_ref| = {err:.3e}")
        assert err < 1e-5, (method, transport, err)

print("multi-device equivalence OK")
