"""Reference (oracle) implementation of the paper's algorithms.

A faithful, loop-over-clusters transcription of Algorithm 1 (HierSignSGD)
and Algorithm 2 (DC-HierSignSGD), plus the two baselines the paper compares
against (HierSGD and the Hier-Local-QSGD-style ternary-quantized variant),
plus the two related-work drift corrections that share DC's pre-sign slot:
SCAFFOLD-style per-client control variates (scaffold_hier_signsgd) and
MTGC's multi-timescale edge/cloud correction (mtgc_hier_signsgd,
arXiv:2409.18448) -- see ``global_round`` for the exact update rules.

This module is the ground truth for the distributed implementation in
``repro.core.hier`` (tested bit-wise equivalent on small problems) and the
engine behind the paper-reproduction experiments (Figs. 2-4).  It carries
the full virtual-client semantics of ``core.clients`` -- per-round client
participation masks, integer |D_qk| vote weights (weighted popcount with
empty-quorum abstention), and participating-share reweighting of the
anchor/mean aggregations -- a "device" here is any client under an edge,
so K virtual clients per slice are simply K more entries per edge
(property-tested in tests/test_ref_fed_participation.py).  Per-client
data assignment is first-class: ``regroup_client_data`` regroups the
nested per-client inputs under a server-side (clustered/random) edge
assignment from ``data.cluster``, mirroring the distributed step's
``core.clients.regroup_clients`` row-block permutation -- every cell of
the parity matrix stays pinned under the intra-edge heterogeneity axis
because heterogeneity only changes WHAT data each client holds, never
the update arithmetic.

Everything operates on flat parameter pytrees; per-device gradients come
from a user-supplied ``grad_fn(params, device_batch, rng) -> grads`` and the
loss surface is arbitrary.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import schedule, signs

PyTree = Any
GradFn = Callable[[PyTree, Any, jax.Array], PyTree]

SIGN_METHODS = ("hier_signsgd", "dc_hier_signsgd", "scaffold_hier_signsgd",
                "mtgc_hier_signsgd")
CLIENT_CORRECTION_METHODS = ("scaffold_hier_signsgd", "mtgc_hier_signsgd")


@dataclasses.dataclass
class HierConfig:
    """Hyper-parameters shared by all hierarchical methods (paper Table I)."""
    mu: float = 5e-3            # step-size (mu)
    t_e: int = 15               # local steps per global round (T_E)
    rho: float = 0.2            # correction strength (DC / scaffold / mtgc)
    method: str = "dc_hier_signsgd"  # hier_sgd | hier_local_qsgd |
                                # hier_signsgd | dc_hier_signsgd |
                                # scaffold_hier_signsgd | mtgc_hier_signsgd
    mu_sgd: float = 1.0         # step-size for the full-precision baselines
    decay: bool = False         # mu_t = mu0/sqrt(t+1) (paper's CIFAR setting)
    cloud_period: int = 2       # mtgc only: rounds between eta refreshes
    cloud_overlap: Any = "sync"  # cloud sync schedule: "sync" | "overlap",
                                # or an explicit ``schedule.CloudSchedule``
                                # (tests use lag=0 through the overlap
                                # machinery to pin the zero-latency-commit
                                # collapse)

    def cloud_schedule(self) -> schedule.CloudSchedule:
        if isinstance(self.cloud_overlap, schedule.CloudSchedule):
            return self.cloud_overlap
        return schedule.CloudSchedule.from_mode(self.cloud_overlap)


@dataclasses.dataclass
class FedState:
    """Cloud + per-edge state across global rounds.

    corr_cl / corr_edge are the scaffold/mtgc correction states
    (lazy-initialized to zeros on the first ``global_round`` once the
    per-edge client counts are known from the batch structure):
    scaffold keeps c_local per client in corr_cl[q][k] and one
    c_global copy per edge in corr_edge[q] (all copies identical --
    the distributed impl's pod-replicated broadcast); mtgc keeps
    gamma_qk in corr_cl[q][k] and eta_q in corr_edge[q]."""
    w: PyTree                         # global model w^(t)
    delta: list[PyTree]               # per-edge correction c^(t-1) - c_q^(t-1)
    round: int = 0
    corr_cl: list[list[PyTree]] | None = None
    corr_edge: list[PyTree] | None = None
    w_inflight: PyTree | None = None  # cloud_overlap="overlap" only: the
                                      # aggregate issued at this round's
                                      # opening boundary, committed one
                                      # boundary later (lazy-initialized
                                      # on the first round to the opening
                                      # weights' sum of Q copies of w --
                                      # what the distributed step-0
                                      # boundary issues from the
                                      # replicated init)


def init_state(w0: PyTree, num_edges: int) -> FedState:
    zeros = lambda: jax.tree.map(jnp.zeros_like, w0)
    return FedState(w=w0, delta=[zeros() for _ in range(num_edges)], round=0)


def _tree_axpy(a: float, x: PyTree, y: PyTree) -> PyTree:
    return jax.tree.map(lambda u, v: a * u + v, x, y)


def _tree_weighted_sum(weights: Sequence[float], trees: Sequence[PyTree]) -> PyTree:
    acc = jax.tree.map(lambda x: weights[0] * x, trees[0])
    for wgt, t in zip(weights[1:], trees[1:]):
        acc = jax.tree.map(lambda a, x: a + wgt * x, acc, t)
    return acc


def regroup_client_data(nested: Sequence[Sequence[Any]], assignment,
                        n_edges: int) -> list[list[Any]]:
    """Per-client data assignment: regroup nested per-client oracle
    inputs (``nested[q][k]`` -- batch lists, anchor batches, vote
    weights, aggregation shares, anything indexed client-first-by-edge)
    under a server-side edge assignment.

    ``assignment[s]`` is the ORIGINAL flat client index (edge-major,
    client k of edge q is ``q*K + k``) that occupies flat slot ``s``
    after regrouping -- the output of
    ``data.cluster.assignment_order``, and the SAME permutation
    ``core.clients.regroup_clients`` applies to the distributed step's
    carved row blocks.  The clustered parity cells pin the two
    implementations against each other: oracle inputs regrouped here
    must produce the trajectory of the distributed step fed the
    regrouped arrays."""
    flat = [c for edge in nested for c in edge]
    idx = [int(i) for i in assignment]
    if sorted(idx) != list(range(len(flat))):
        raise ValueError(
            f"assignment must permute all {len(flat)} clients: {idx}")
    if len(flat) % n_edges:
        raise ValueError(
            f"{len(flat)} clients do not fill {n_edges} equal edges")
    cap = len(flat) // n_edges
    return [[flat[idx[q * cap + j]] for j in range(cap)]
            for q in range(n_edges)]


def _participating_shares(weights: Sequence[float],
                          mask: Sequence[bool] | None) -> list[float]:
    """Per-edge aggregation shares renormalized to the participating
    clients: w_k m_k / sum_j w_j m_j (python-float arithmetic; all
    zeros when the whole edge is masked out, so the aggregate is the
    zero tree)."""
    m = ([1.0] * len(weights) if mask is None
         else [1.0 if b else 0.0 for b in mask])
    raw = [float(w) * mm for w, mm in zip(weights, m)]
    tot = sum(raw)
    return [r / tot if tot > 0 else 0.0 for r in raw]


def global_round(
    state: FedState,
    cfg: HierConfig,
    grad_fn: GradFn,
    batches: Sequence[Sequence[Any]],       # batches[q][k] -> iterator of T_E device batches
    anchor_batches: Sequence[Sequence[Any]],  # anchor_batches[q][k] -> one batch per device
    edge_weights: Sequence[float],          # D_q / N
    device_weights: Sequence[Sequence[float]],  # |D_qk| / D_q
    rng: jax.Array,
    device_mask: Sequence[Sequence[bool]] | None = None,
    vote_weights: Sequence[Sequence[int]] | None = None,
    reweight_participation: bool = False,
    device_mask_steps: Sequence[Sequence[Sequence[bool]]] | None = None,
    edge_weights_agg: Sequence[float] | None = None,
) -> FedState:
    """Run one global round t (T_E local steps + cloud aggregation).

    Transcribes Algorithm 2 exactly; Algorithm 1 is the rho=0 / no-anchor
    special case; baselines replace the sign/vote with full-precision or
    ternary-quantized averaging.

    Virtual-client semantics (mirroring ``core.hier``'s active
    ``ClientConfig``): a "device" k here is any client under edge q --
    virtual clients are simply more entries in ``batches[q]``.

    device_mask: per-client participation of THIS round ({0,1}; the
        distributed impl draws it from the pinned (seed, round) scheme
        of ``core.clients`` -- one round, one mask).
    vote_weights: optional integer data shares |D_qk| weighting the
        majority vote (weighted popcount, combined with the mask; an
        edge whose whole quorum abstains votes 0, leaving v_q unchanged
        for the round -- ties still resolve sgn(0)=+1).  ``None`` keeps
        the unit-weight vote.
    reweight_participation: renormalize ``device_weights`` to the
        participating clients for the anchor pass and the
        full-precision edge means (``device_weights`` may then be
        UNNORMALIZED raw shares).  False keeps the legacy behavior
        (mask gates the vote only) bit-for-bit.
    device_mask_steps: optional per-local-step masks (length ``t_e``;
        chaos-schedule semantics: local step tau uses
        ``device_mask_steps[tau]``, mirroring the distributed step
        where the membership mask is a fresh runtime input every step,
        while the pinned participation draw is per round).
        ``device_mask`` stays the ROUND mask -- it gates the anchor
        shares and the correction-state refresh, exactly like the
        distributed round prologue (= the tau-0 mask under churn).
    edge_weights_agg: optional cloud-aggregation weights for THIS
        round's closing ``w_next`` (default ``edge_weights``).  The
        distributed step aggregates round t in the prologue of step
        (t+1)*T_E, i.e. with the NEXT round's edge weights -- under
        membership churn the two differ.
    """
    q_edges = len(batches)
    mu = cfg.mu if cfg.method in SIGN_METHODS else cfg.mu_sgd
    if cfg.decay:
        mu = mu / jnp.sqrt(state.round + 1.0)

    # ---- cloud sync schedule (core.schedule): under "overlap" the round
    # runs from the COMMITTED (one-boundary-stale) aggregate -- which is
    # exactly ``state.w`` here, committed by the previous call -- while
    # ``state.w_inflight`` holds the aggregate issued at this round's
    # opening boundary, to be committed at the close.  Lazy first-round
    # init: the edges all hold w0 at the opening boundary, so the issued
    # aggregate is the opening weights' sum of Q copies of w (what the
    # distributed step-0 prologue issues from the replicated init).
    sched = cfg.cloud_schedule()
    w_inflight = state.w_inflight
    if sched.staged and w_inflight is None:
        w_inflight = _tree_weighted_sum(
            [float(x) for x in edge_weights], [state.w] * q_edges)

    def edge_shares(q, mask=None):
        if not reweight_participation:
            return device_weights[q]
        if mask is None:
            mask = device_mask
        return _participating_shares(
            device_weights[q], None if mask is None else mask[q])

    new_delta = list(state.delta)
    edge_models: list[PyTree] = []
    anchors_cq: list[PyTree] = []

    # ---- anchor gradients at w^(t) (DC only): c_q^(t) = sum_k w_qk grad f_qk(w)
    if cfg.method == "dc_hier_signsgd":
        for q in range(q_edges):
            g_devs = []
            for k in range(len(anchor_batches[q])):
                rng, sub = jax.random.split(rng)
                g_devs.append(grad_fn(state.w, anchor_batches[q][k], sub))
            anchors_cq.append(_tree_weighted_sum(edge_shares(q), g_devs))
        c_glob = _tree_weighted_sum(edge_weights, anchors_cq)

    # ---- scaffold / mtgc correction refresh at w^(t) (fresh semantics:
    # the refreshed state is used by THIS round's local steps, mirroring
    # hier.compute_corrections in the round prologue)
    corr_cl, corr_edge = state.corr_cl, state.corr_edge
    if cfg.method in CLIENT_CORRECTION_METHODS:
        zeros = lambda: jax.tree.map(jnp.zeros_like, state.w)
        if corr_cl is None:
            corr_cl = [[zeros() for _ in anchor_batches[q]]
                       for q in range(q_edges)]
        if corr_edge is None:
            corr_edge = [zeros() for _ in range(q_edges)]

        def participates(q, k):
            """The distributed impl's EF carry-forward gate (vote weight
            > 0): only meaningful on the reweighting (virtual-client)
            path; the legacy path updates unconditionally."""
            if not reweight_participation:
                return True
            ok = device_mask is None or bool(device_mask[q][k])
            if vote_weights is not None:
                ok = ok and vote_weights[q][k] > 0
            return ok

        anchors = []
        for q in range(q_edges):
            g_devs = []
            for k in range(len(anchor_batches[q])):
                rng, sub = jax.random.split(rng)
                g_devs.append(grad_fn(state.w, anchor_batches[q][k], sub))
            anchors.append(g_devs)

        if cfg.method == "scaffold_hier_signsgd":
            # c_global absorbs the share-weighted drift sum_qk (a - c_local)
            # (abstainers enter with zero participating share), THEN the
            # participating clients refresh c_local <- a_qk -- option-I
            # control variates; telescopes under full participation.
            upd = [_tree_weighted_sum(
                       edge_shares(q),
                       [jax.tree.map(lambda a, c: a - c, anchors[q][k],
                                     corr_cl[q][k])
                        for k in range(len(anchors[q]))])
                   for q in range(q_edges)]
            drift = _tree_weighted_sum(edge_weights, upd)
            corr_edge = [jax.tree.map(lambda e, d: e + d, corr_edge[q],
                                      drift)
                         for q in range(q_edges)]
            corr_cl = [[anchors[q][k] if participates(q, k)
                        else corr_cl[q][k]
                        for k in range(len(anchors[q]))]
                       for q in range(q_edges)]
        else:  # mtgc: gamma every round, eta every cloud_period rounds;
            # an edge whose whole quorum abstains keeps BOTH its terms
            # (c still sums the abstained edges' zero c_q, like DC)
            c_qs = [_tree_weighted_sum(edge_shares(q), anchors[q])
                    for q in range(q_edges)]
            c = _tree_weighted_sum(edge_weights, c_qs)
            if state.round % cfg.cloud_period == 0:
                corr_edge = [
                    jax.tree.map(lambda u, v: u - v, c, c_qs[q])
                    if any(participates(q, k)
                           for k in range(len(anchors[q])))
                    else corr_edge[q]
                    for q in range(q_edges)]
            corr_cl = [[jax.tree.map(lambda u, v: u - v, c_qs[q],
                                     anchors[q][k])
                        if participates(q, k) else corr_cl[q][k]
                        for k in range(len(anchors[q]))]
                       for q in range(q_edges)]

    # ---- T_E local steps per edge (paper: in parallel over q)
    for q in range(q_edges):
        v = state.w
        delta_q = state.delta[q]
        for tau in range(cfg.t_e):
            # churn semantics: the membership mask of local step tau
            # (the distributed step reads fresh membership arrays every
            # step; the round mask is the tau-0 / prologue view)
            mask_tau = (device_mask if device_mask_steps is None
                        else device_mask_steps[tau])
            g_devs = []
            for k in range(len(batches[q])):
                rng, sub = jax.random.split(rng)
                g_devs.append(grad_fn(v, batches[q][k][tau], sub))

            if cfg.method in SIGN_METHODS:
                # device-side (corrected) sign -> 1-bit uplink -> majority
                # vote; scaffold/mtgc put their per-client correction in
                # the same pre-sign slot as DC's shared delta
                if cfg.method == "dc_hier_signsgd":
                    sign_devs = [jax.tree.map(
                        lambda g, d: signs.sgn(g + cfg.rho * d),
                        g, delta_q) for g in g_devs]
                elif cfg.method == "scaffold_hier_signsgd":
                    sign_devs = [jax.tree.map(
                        lambda g, e, cv: signs.sgn(g + cfg.rho * (e - cv)),
                        g_devs[k], corr_edge[q], corr_cl[q][k])
                        for k in range(len(g_devs))]
                elif cfg.method == "mtgc_hier_signsgd":
                    sign_devs = [jax.tree.map(
                        lambda g, cv, e: signs.sgn(g + cfg.rho * (cv + e)),
                        g_devs[k], corr_cl[q][k], corr_edge[q])
                        for k in range(len(g_devs))]
                else:
                    sign_devs = [jax.tree.map(signs.sgn, g)
                                 for g in g_devs]
                mask_q = None
                if mask_tau is not None:
                    mask_q = jnp.asarray(mask_tau[q], dtype=jnp.int32)
                if vote_weights is not None:
                    vw = jnp.asarray(vote_weights[q], dtype=jnp.int32)
                    mask_q = vw if mask_q is None else vw * mask_q
                vote = jax.tree.map(
                    lambda *s: signs.majority_vote(jnp.stack(s), mask_q, axis=0),
                    *sign_devs,
                )
                v = jax.tree.map(lambda p, s: p - mu * s.astype(p.dtype), v, vote)
            elif cfg.method == "hier_sgd":
                g_edge = _tree_weighted_sum(edge_shares(q, mask_tau),
                                            g_devs)
                v = _tree_axpy(-mu, g_edge, v)
            elif cfg.method == "hier_local_qsgd":
                q_devs = []
                for g in g_devs:
                    rng, sub = jax.random.split(rng)
                    leaves, treedef = jax.tree.flatten(g)
                    subs = jax.random.split(sub, len(leaves))
                    q_devs.append(treedef.unflatten([
                        signs.ternary_quantize(l, r) for l, r in zip(leaves, subs)
                    ]))
                g_edge = _tree_weighted_sum(edge_shares(q, mask_tau),
                                            q_devs)
                v = _tree_axpy(-mu, g_edge, v)
            else:
                raise ValueError(cfg.method)
        edge_models.append(v)
        if cfg.method == "dc_hier_signsgd":
            new_delta[q] = jax.tree.map(lambda c, cq: c - cq, c_glob, anchors_cq[q])

    # ---- cloud aggregation: w^(t+1) = sum_q (D_q/N) v_q^(t, T_E)
    # (under membership churn the closing weights are the NEXT round's
    # edge weights -- the distributed prologue's view; see
    # ``edge_weights_agg``).  The schedule decides what lands: sync
    # commits the freshly issued aggregate; overlap commits the one
    # issued at this round's OPENING boundary (``w_inflight``, its
    # weights pinned to issue time) and stages the fresh one.
    issued = _tree_weighted_sum(
        edge_weights if edge_weights_agg is None else edge_weights_agg,
        edge_models)
    w_next, w_inflight = sched.commit(issued, w_inflight)
    return FedState(w=w_next, delta=new_delta, round=state.round + 1,
                    corr_cl=corr_cl, corr_edge=corr_edge,
                    w_inflight=w_inflight)
