"""Deterministic synthetic LM token pipeline with two-level heterogeneity.

The paper's setting is *inter-cluster* statistical heterogeneity (edges
skewed).  For LM training we emulate multi-region ingestion: each edge q
draws tokens from its own Zipf-like unigram distribution (a per-edge
permutation + temperature of a shared base distribution, mixing-parameter
alpha -> uniform mixing = IID).  On top of that, ``alpha_client`` adds
*intra-edge* heterogeneity: each virtual client tilts its edge's unigram
by a per-client Dirichlet(alpha_client) reweighting, so the K clients
carved from one device batch (``core.clients.carve_batch``) stream from
genuinely distinct distributions -- client c's rows of the [P, D, b, L]
batch are drawn from ITS logits, matching the carve contract (rows
[c*b/K, (c+1)*b/K) of slice d belong to voter d*K + c).
``alpha_client=None`` (default) or ``inf`` keeps the legacy per-edge
stream bitwise.

``edge_assign`` regroups clients across edges before streaming:
``random`` scatters them uniformly (seeded), ``clustered`` groups them
by unigram-sketch similarity (``data.cluster`` -- deterministic,
permutation-invariant, and only the aggregate sketch crosses tiers).

Everything is cursor-addressable: ``batch_at(step)`` is a pure function of
(seed, step), so restoring a checkpointed step counter exactly resumes the
stream (no iterator state to persist).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import cluster


@dataclasses.dataclass(frozen=True)
class LMStreamCfg:
    vocab: int
    seq_len: int
    batch_per_device: int
    pods: int
    devices_per_pod: int
    seed: int = 0
    skew: float = 1.2          # Zipf exponent of the base distribution
    hetero: float = 1.0        # 0 = IID edges, 1 = fully per-edge skewed
    clients_per_device: int = 1  # K virtual clients per slice: the train
                                 # step carves each device batch into K
                                 # contiguous per-client shards
                                 # (core.clients.carve_batch), so
                                 # batch_per_device must divide by K;
                                 # with alpha_client=None the K clients
                                 # share the edge distribution (the
                                 # paper's inter-edge-only setting)
    alpha_client: float | None = None  # intra-edge Dirichlet tilt of
                                 # each client's unigram; None or inf =
                                 # legacy per-edge stream, bitwise
    edge_assign: str = "fixed"   # fixed | random | clustered (see
                                 # data.cluster)
    frames: int = 0            # audio stub frontend
    frontend_dim: int = 0
    n_patches: int = 0         # vlm stub frontend
    d_model: int = 0


def _edge_logits(cfg: LMStreamCfg) -> np.ndarray:
    """[P, V] unigram logits per edge (numpy, deterministic)."""
    rng = np.random.default_rng(cfg.seed)
    base = -cfg.skew * np.log(np.arange(1, cfg.vocab + 1))
    logits = np.zeros((cfg.pods, cfg.vocab), np.float32)
    for q in range(cfg.pods):
        perm = rng.permutation(cfg.vocab)
        edge = base[perm]                       # edge-specific Zipf ranks
        logits[q] = cfg.hetero * edge + (1.0 - cfg.hetero) * base
    return logits


def _client_skew_active(cfg: LMStreamCfg) -> bool:
    return cfg.alpha_client is not None and np.isfinite(cfg.alpha_client)


def _client_logits(cfg: LMStreamCfg) -> np.ndarray:
    """[P, D, K, V] per-virtual-client unigram logits (numpy,
    deterministic): the edge logits tilted by log(V * Dirichlet
    (alpha_client)) per client -- a mean-zero perturbation in
    distribution space that vanishes as alpha_client -> inf -- then
    regrouped across edges per ``edge_assign``."""
    p, d, k = cfg.pods, cfg.devices_per_pod, cfg.clients_per_device
    out = np.broadcast_to(_edge_logits(cfg)[:, None, None, :],
                          (p, d, k, cfg.vocab)).copy()
    if _client_skew_active(cfg):
        rng = np.random.default_rng((cfg.seed, 0xA1FA))
        mix = rng.dirichlet(np.full(cfg.vocab, cfg.alpha_client),
                            size=(p, d, k))
        out += np.log(np.maximum(mix * cfg.vocab, 1e-20)).astype(
            np.float32)
    if cfg.edge_assign != "fixed":
        flat = out.reshape(p * d * k, cfg.vocab)
        if cfg.edge_assign == "random":
            assign = cluster.random_assignment(p * d * k, p, cfg.seed)
        else:
            # unigram sketches: each client contributes ONE aggregate
            # [V] distribution (softmax of its logits), never tokens
            probs = np.exp(flat - flat.max(axis=1, keepdims=True))
            sigs = cluster.sketch_signatures(
                probs / probs.sum(axis=1, keepdims=True))
            assign = cluster.cluster_edges(sigs, p)
        out = flat[cluster.assignment_order(assign, p)].reshape(out.shape)
    return out


def validate_scenario(cfg: LMStreamCfg) -> None:
    """Scenario-axis validation shared with the launch CLIs (they call
    this up front so a bad flag combination rejects before tracing)."""
    if cfg.edge_assign not in cluster.EDGE_ASSIGN_MODES:
        raise ValueError(
            f"unknown edge_assign {cfg.edge_assign!r}; expected one of "
            f"{cluster.EDGE_ASSIGN_MODES}")
    if cfg.alpha_client is not None and cfg.alpha_client <= 0:
        raise ValueError(
            f"alpha_client must be positive (or None): {cfg.alpha_client}")
    if cfg.edge_assign == "clustered":
        if cfg.clients_per_device == 1:
            raise ValueError(
                "clustered edge assignment regroups VIRTUAL clients, so "
                "the client carve must be active: clients_per_device > 1 "
                "(--clients_per_device)")
        if not _client_skew_active(cfg):
            raise ValueError(
                "clustered edge assignment needs --alpha_client: without "
                "intra-edge skew the edge's clients are identical and "
                "there is nothing to cluster")


def make_stream(cfg: LMStreamCfg):
    """Returns batch_at(step) -> batch pytree of [P, D, b, ...].

    The stream always emits physical-slice batches; virtual-client
    carving is the train step's local reshape (with alpha_client
    active, client c's rows are sampled from its own tilted unigram, so
    the carve recovers per-client distributions).  Validates the carve
    contract and the scenario axes up front so a bad K / edge_assign
    fails at stream construction, not steps into a jitted error."""
    if cfg.batch_per_device % cfg.clients_per_device:
        raise ValueError(
            f"batch_per_device={cfg.batch_per_device} does not divide "
            f"into {cfg.clients_per_device} virtual clients per device")
    validate_scenario(cfg)
    per_client = _client_skew_active(cfg) or cfg.edge_assign != "fixed"
    logits = jnp.asarray(_client_logits(cfg) if per_client
                         else _edge_logits(cfg))
    k_c = cfg.clients_per_device
    rows = cfg.batch_per_device // k_c

    def batch_at(step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        shape = (cfg.pods, cfg.devices_per_pod, cfg.batch_per_device,
                 cfg.seq_len)
        keys = jax.random.split(key, cfg.pods)
        if per_client:
            toks = jnp.stack([
                jax.random.categorical(
                    keys[q], logits[q][:, :, None, None, :],
                    shape=(cfg.devices_per_pod, k_c, rows, cfg.seq_len))
                for q in range(cfg.pods)]).reshape(shape)
        else:
            toks = jnp.stack([
                jax.random.categorical(keys[q], logits[q], shape=shape[1:])
                for q in range(cfg.pods)])
        batch = {"tokens": toks.astype(jnp.int32)}
        if cfg.frames:
            kf = jax.random.fold_in(key, 1)
            batch["frames"] = 0.1 * jax.random.normal(
                kf, (cfg.pods, cfg.devices_per_pod, cfg.batch_per_device,
                     cfg.frames, cfg.frontend_dim))
        if cfg.n_patches:
            kp = jax.random.fold_in(key, 2)
            batch["patches"] = 0.02 * jax.random.normal(
                kp, (cfg.pods, cfg.devices_per_pod, cfg.batch_per_device,
                     cfg.n_patches, cfg.d_model))
        return batch

    return batch_at


def serve_request_batch(cfg: LMStreamCfg, n_requests: int, prompt_len: int,
                        seed: int = 17):
    """Batched serving requests (prompts) for the serve example."""
    key = jax.random.PRNGKey(seed)
    return {"tokens": jax.random.randint(
        key, (n_requests, prompt_len), 0, cfg.vocab, jnp.int32)}
