"""Deterministic synthetic LM token pipeline with inter-edge heterogeneity.

The paper's setting is *inter-cluster* statistical heterogeneity (devices
within an edge IID; edges skewed).  For LM training we emulate multi-region
ingestion: each edge q draws tokens from its own Zipf-like unigram
distribution (a per-edge permutation + temperature of a shared base
distribution, mixing-parameter alpha -> uniform mixing = IID).

Everything is cursor-addressable: ``batch_at(step)`` is a pure function of
(seed, step), so restoring a checkpointed step counter exactly resumes the
stream (no iterator state to persist).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LMStreamCfg:
    vocab: int
    seq_len: int
    batch_per_device: int
    pods: int
    devices_per_pod: int
    seed: int = 0
    skew: float = 1.2          # Zipf exponent of the base distribution
    hetero: float = 1.0        # 0 = IID edges, 1 = fully per-edge skewed
    clients_per_device: int = 1  # K virtual clients per slice: the train
                                 # step carves each device batch into K
                                 # contiguous per-client shards
                                 # (core.clients.carve_batch), so
                                 # batch_per_device must divide by K;
                                 # within-edge clients stay IID (the
                                 # paper's setting -- heterogeneity is
                                 # inter-edge)
    frames: int = 0            # audio stub frontend
    frontend_dim: int = 0
    n_patches: int = 0         # vlm stub frontend
    d_model: int = 0


def _edge_logits(cfg: LMStreamCfg) -> np.ndarray:
    """[P, V] unigram logits per edge (numpy, deterministic)."""
    rng = np.random.default_rng(cfg.seed)
    base = -cfg.skew * np.log(np.arange(1, cfg.vocab + 1))
    logits = np.zeros((cfg.pods, cfg.vocab), np.float32)
    for q in range(cfg.pods):
        perm = rng.permutation(cfg.vocab)
        edge = base[perm]                       # edge-specific Zipf ranks
        logits[q] = cfg.hetero * edge + (1.0 - cfg.hetero) * base
    return logits


def make_stream(cfg: LMStreamCfg):
    """Returns batch_at(step) -> batch pytree of [P, D, b, ...].

    The stream always emits physical-slice batches; virtual-client
    carving is the train step's local reshape.  Validates the carve
    contract up front so a bad K fails at stream construction, not
    steps into a jitted reshape error."""
    if cfg.batch_per_device % cfg.clients_per_device:
        raise ValueError(
            f"batch_per_device={cfg.batch_per_device} does not divide "
            f"into {cfg.clients_per_device} virtual clients per device")
    logits = jnp.asarray(_edge_logits(cfg))

    def batch_at(step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        shape = (cfg.pods, cfg.devices_per_pod, cfg.batch_per_device,
                 cfg.seq_len)
        keys = jax.random.split(key, cfg.pods)
        toks = jnp.stack([
            jax.random.categorical(keys[q], logits[q], shape=shape[1:])
            for q in range(cfg.pods)])
        batch = {"tokens": toks.astype(jnp.int32)}
        if cfg.frames:
            kf = jax.random.fold_in(key, 1)
            batch["frames"] = 0.1 * jax.random.normal(
                kf, (cfg.pods, cfg.devices_per_pod, cfg.batch_per_device,
                     cfg.frames, cfg.frontend_dim))
        if cfg.n_patches:
            kp = jax.random.fold_in(key, 2)
            batch["patches"] = 0.02 * jax.random.normal(
                kp, (cfg.pods, cfg.devices_per_pod, cfg.batch_per_device,
                     cfg.n_patches, cfg.d_model))
        return batch

    return batch_at


def serve_request_batch(cfg: LMStreamCfg, n_requests: int, prompt_len: int,
                        seed: int = 17):
    """Batched serving requests (prompts) for the serve example."""
    key = jax.random.PRNGKey(seed)
    return {"tokens": jax.random.randint(
        key, (n_requests, prompt_len), 0, cfg.vocab, jnp.int32)}
