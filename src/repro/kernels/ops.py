"""Public jit'd wrappers around the Pallas kernels.

Handles arbitrary pytree/shape inputs (flatten -> pad -> 2D view ->
kernel -> unpad) and **backend-detects** instead of hardcoding a mode:

  * on TPU (``jax.default_backend() == "tpu"``) the compiled Pallas
    kernels run by default (``interpret=False``);
  * elsewhere the pure-jnp reference runs by default (interpret-mode
    Pallas is available on request for validation -- it is far slower
    than the reference, so it is never the silent default).

Pass ``use_pallas=``/``interpret=`` explicitly to override (the kernel
tests force ``use_pallas=True, interpret=True`` on CPU).

2D views are layout-cached: the (rows, pad) arithmetic for a given
(n, block) is computed once per process, and inputs whose flat size is
already block-aligned (everything produced by ``core.flatbuf``) are pure
reshape views -- no concatenate, no pad.

``fused_sign_vote_flat`` is the vote-only local compute of the fused
transport; ``fused_vote_update_flat`` (state_layout="flat") additionally
applies ``v <- v - mu*vote`` inside the single ``vote_update``
read-modify-write, so the whole-model update is one HBM pass (aliased
in place when compiled).  Both are compositions of the two halves the
multi-chip shard_map program calls directly with the data-axis gather
in between: ``fused_pack_flat`` (device-side sign+pack, pre-gather) and
``fused_vote_update_words`` (edge-side vote+update on the gathered
words) -- see ``core.votes``.

Padding contract: the flat views these wrappers sweep may contain
don't-care coordinates BETWEEN real leaves, not just at the buffer
tail -- slot tail padding and, in per-rank bucket buffers of a sharded
layout, the zero shard tail of an uneven TP leaf's last block
(``flatbuf.LeafSlot.shard_pad``).  All of them are zero floats, so the
kernels see ``sgn(0) = +1`` and update them like any coordinate; no
view ever reads them back, which is what makes the whole-buffer sweep
legal without per-leaf masks.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref, sign_pack as _sp, tally_acc as _ta
from repro.kernels import ternary_quant as _tq, vote_update as _vu

PACK = 32


# ---------------------------------------------------------------------------
# Backend detection
# ---------------------------------------------------------------------------

def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas: bool | None, interpret: bool | None):
    """None -> backend defaults: compiled Pallas on TPU, jnp ref elsewhere."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if interpret is None:
        interpret = not on_tpu()
    return use_pallas, interpret


def fused_kernel_mode(mesh_size: int, shard_mapped: bool = False) -> str:
    """How the fused flat-buffer transport should run its local compute.

    Returns ``"pallas"`` (compiled), ``"interpret"`` or ``"jnp"``.  The
    Pallas kernels are single-device programs; outside ``shard_map``
    they only engage when the mesh has one device (single-chip runs /
    per-host simulation) and multi-device GSPMD meshes take the
    pure-jnp path, whose collectives partition correctly.  With
    ``shard_mapped=True`` the caller is building a per-rank shard_map
    program -- every rank is a single device there, so the compiled
    kernels engage on TPU at ANY mesh size (this is the multi-chip
    fused path of ``core.votes``).  ``REPRO_FUSED_PALLAS`` overrides:
    ``off`` forces jnp, ``interpret`` forces interpret-mode Pallas
    (used by tests to exercise the kernel route on CPU).
    """
    env = os.environ.get("REPRO_FUSED_PALLAS", "auto").lower()
    if env in ("0", "off", "jnp"):
        return "jnp"
    if env == "interpret":
        return "interpret"
    if (shard_mapped or mesh_size == 1) and on_tpu():
        return "pallas"
    return "jnp"


# ---------------------------------------------------------------------------
# Layout-cached 2D views
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _pad_layout(n: int, block_r: int, block_c: int):
    """Static (rows, pad) so that rows % block_r == 0, rows*block_c >= n."""
    rows = -(-n // block_c)
    rows = -(-rows // block_r) * block_r
    return rows, rows * block_c - n


def _to_2d(x: jax.Array, block_r: int, block_c: int):
    """Flatten to an [R, C] view divisible by the block.

    Block-aligned inputs (flatbuf buffers) reshape in place; ragged tails
    get one zero-pad (sgn(0) = +1: bit-identical to the old ones-padding).
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows, pad = _pad_layout(n, block_r, block_c)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, block_c), n


@functools.lru_cache(maxsize=None)
def _row_block(rows: int, block_r: int) -> int:
    """Largest power-of-two divisor of ``rows`` that is <= block_r."""
    b = 1
    while b < block_r and rows % (2 * b) == 0:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# N-d kernel wrappers
# ---------------------------------------------------------------------------

def sign_pack_nd(g: jax.Array, delta: jax.Array | None = None,
                 rho: float = 0.0, *, use_pallas: bool | None = None,
                 interpret: bool | None = None,
                 block_r: int = _sp.BLOCK_R, block_c: int = _sp.BLOCK_C):
    """Any-shape g (+delta) -> (packed [n_words] uint32, n_coords)."""
    use_pallas, interpret = _resolve(use_pallas, interpret)
    g2, n = _to_2d(g, block_r, block_c)
    d2 = None
    if delta is not None:
        d2, _ = _to_2d(delta.astype(g.dtype), block_r, block_c)
    if use_pallas:
        packed = _sp.sign_pack(g2, d2, rho, block_r=block_r,
                               block_c=block_c, interpret=interpret)
    else:
        packed = ref.sign_pack_ref(g2, d2, rho)
    return packed.reshape(-1), n


def vote_update_nd(packed_rows: jax.Array, v: jax.Array,
                   mask: jax.Array | None = None, *, mu: float,
                   use_pallas: bool | None = None,
                   interpret: bool | None = None,
                   block_r: int = _vu.BLOCK_R, block_c: int = _vu.BLOCK_C):
    """packed_rows: [K, n_words] (from sign_pack_nd on each device);
    v: any-shape model tensor.  Returns updated v."""
    use_pallas, interpret = _resolve(use_pallas, interpret)
    k = packed_rows.shape[0]
    v2, n = _to_2d(v, block_r, block_c)
    r, c = v2.shape
    packed = packed_rows.reshape(k, r, c // PACK)
    if use_pallas:
        out = _vu.vote_update(packed, v2, mask, mu=mu, block_r=block_r,
                              block_c=block_c, interpret=interpret)
    else:
        out = ref.vote_update_ref(packed, v2, mu, mask)
    return out.reshape(-1)[:n].reshape(v.shape)


def ternary_quant_nd(x: jax.Array, rng: jax.Array, *,
                     use_pallas: bool | None = None,
                     interpret: bool | None = None,
                     block_r: int = _tq.BLOCK_R, block_c: int = _tq.BLOCK_C):
    """Any-shape unbiased ternary quantization (baseline compressor)."""
    use_pallas, interpret = _resolve(use_pallas, interpret)
    x2, n = _to_2d(x, block_r, block_c)
    # _to_2d zero-pads, so the padding cannot influence the norm
    norm = jnp.linalg.norm(x2.astype(jnp.float32))
    u = jax.random.uniform(rng, x2.shape, jnp.float32)
    if use_pallas:
        out = _tq.ternary_quant(x2, u, norm, block_r=block_r,
                                block_c=block_c, interpret=interpret)
    else:
        out = ref.ternary_quant_ref(x2, u, norm)
    return out.reshape(-1)[:n].reshape(x.shape)


# ---------------------------------------------------------------------------
# Fused flat-buffer transport (local compute of core.votes "fused")
# ---------------------------------------------------------------------------

def fused_pack_flat(u_buf: jax.Array, d_buf: jax.Array | None,
                    rho: float, *, interpret: bool) -> jax.Array:
    """Device-side half of the fused transport: flat floats -> packed words.

    u_buf: [P, D, n_pad] float (n_pad % 4096 == 0, from core.flatbuf);
    d_buf: [P, n_pad] correction or None (the caller only folds the DC
    correction here for all-f32 trees -- the kernel adds in f32, which
    is exact iff the reference arithmetic is f32 too).  Returns the
    1-bit uplink payload [P, D, n_pad/32] uint32 via ONE ``sign_pack``
    sweep over all P*D rows (delta re-read per voter through its
    BlockSpec, never broadcast-copied).  This is the pre-gather half the
    multi-chip shard_map program runs per rank before the data-axis
    all-gather of the words (``core.votes``).
    """
    p, d, n = u_buf.shape
    packed, _, _ = _sign_pack_slabs(u_buf, d_buf, rho, interpret)
    return packed.reshape(p, d, n // PACK)


def fused_vote_update_words(words: jax.Array, v_buf: jax.Array | None,
                            mask: jax.Array | None, mu: float, *,
                            interpret: bool) -> jax.Array:
    """Edge-side half: packed voter words -> vote (+ optional update).

    words: [P, D, n_words] uint32 (all D voters' payloads, e.g. after
    the data-axis gather; D may be the merged virtual-client axis D*K);
    v_buf: [P, n_pad] float master buffer, or None to compute a pure
    vote (v = 0, mu = -1 makes the fused update emit exactly
    ``MajorityVote``); mask: [P, D] voter mask, nonnegative integer
    vote weights (weighted popcount; an empty quorum abstains and
    leaves v untouched), or None.
    ONE ``vote_update`` read-modify-write per pod over the whole-model
    packed-word buffer.
    """
    p, d, w = words.shape
    n = w * PACK
    block_c = _vu.BLOCK_C
    rows = n // block_c
    assert n % block_c == 0, (n, block_c)
    packed = words.reshape(p, d, rows, block_c // PACK)
    v2 = None if v_buf is None else v_buf.reshape(p, rows, block_c)
    zeros = (jnp.zeros((rows, block_c), jnp.float32) if v_buf is None
             else None)
    brv = _row_block(rows, _vu.BLOCK_R)
    out = []
    for q in range(p):                     # P is small and static
        m_q = mask[q] if mask is not None else None
        out.append(_vu.vote_update(packed[q],
                                   zeros if v2 is None else v2[q],
                                   m_q, mu=mu, block_r=brv,
                                   block_c=block_c, interpret=interpret))
    return jnp.stack(out).reshape(p, n)


def fused_sign_vote_flat(u_buf: jax.Array, d_buf: jax.Array | None,
                         rho: float, mask: jax.Array | None, *,
                         interpret: bool) -> jax.Array:
    """Pallas route of the fused transport on a local flat buffer.

    Composition of :func:`fused_pack_flat` and
    :func:`fused_vote_update_words` with v = 0, mu = -1 (pure vote).
    Returns the per-pod vote [P, n_pad] int8.
    """
    words = fused_pack_flat(u_buf, d_buf, rho, interpret=interpret)
    vote = fused_vote_update_words(words, None, mask, -1.0,
                                   interpret=interpret)
    return vote.astype(jnp.int8)


def _sign_pack_slabs(u_buf: jax.Array, d_buf: jax.Array | None, rho: float,
                     interpret: bool):
    """[P, D, n] float -> ([P, D, rows, words/row] packed, rows, block_c)."""
    p, d, n = u_buf.shape
    block_c = _sp.BLOCK_C
    rows = n // block_c
    assert n % block_c == 0, (n, block_c)
    g2 = u_buf.reshape(p * d * rows, block_c)
    br = _row_block(rows, _sp.BLOCK_R)
    d2 = None
    if d_buf is not None and rho:
        d2 = d_buf.astype(u_buf.dtype).reshape(p * rows, block_c)
    packed = _sp.sign_pack(g2, d2, rho, block_r=br, block_c=block_c,
                           interpret=interpret, slab_rows=rows)
    return packed.reshape(p, d, rows, block_c // PACK), rows, block_c


def fused_tally_acc_flat(u_buf: jax.Array, d_buf: jax.Array | None,
                         rho: float, weights: jax.Array,
                         tally: jax.Array, *, interpret: bool) -> jax.Array:
    """Streamed-client local step: fold ONE client's signs into the tally.

    u_buf: [P, D, n_pad] float pre-sign directions of the current
    client (physical device axis, NOT the merged D*K); d_buf: [P, n_pad]
    correction or None (same fold rules as ``fused_pack_flat``);
    weights: [P, D] integer vote weights of this client; tally:
    [P, D, n_pad] signed tally (int8/int16/int32 per
    ``core.votes.tally_dtype``).  ONE ``tally_acc`` read-modify-write
    sweep over all P*D rows -- the client's sign plane never reaches
    HBM, and the delta block is re-read per voter through its BlockSpec
    exactly like ``fused_pack_flat``.
    """
    p, d, n = u_buf.shape
    assert tally.shape == (p, d, n), (tally.shape, u_buf.shape)
    block_c = _ta.BLOCK_C
    rows = n // block_c
    assert n % block_c == 0, (n, block_c)
    g2 = u_buf.reshape(p * d * rows, block_c)
    t2 = tally.reshape(p * d * rows, block_c)
    d2 = None
    if d_buf is not None and rho:
        d2 = d_buf.astype(u_buf.dtype).reshape(p * rows, block_c)
    br = _row_block(rows, _ta.BLOCK_R)
    w2 = weights.reshape(p * d, 1)
    out = _ta.tally_acc(g2, d2, w2, t2, rho=rho, block_r=br,
                        block_c=block_c, interpret=interpret,
                        slab_rows=rows)
    return out.reshape(p, d, n)


def fused_vote_update_flat(u_buf: jax.Array, d_buf: jax.Array | None,
                           rho: float, mask: jax.Array | None,
                           v_buf: jax.Array, mu: float, *,
                           interpret: bool) -> jax.Array:
    """Flat-state fused local step: ``v <- v - mu * vote`` on the buffer.

    u_buf: [P, D, n_pad] float pre-sign directions; d_buf: [P, n_pad]
    correction or None (same fold rules as ``fused_sign_vote_flat``);
    v_buf: [P, n_pad] master buffer; mu: static step size.  One
    ``sign_pack`` sweep over all P*D rows, then exactly ONE
    ``vote_update`` read-modify-write per pod over the whole-model
    packed-word buffer -- the vote never materializes, the update is the
    kernel's single HBM pass over v (aliased in place when compiled).
    """
    p, d, n = u_buf.shape
    assert v_buf.shape == (p, n), (v_buf.shape, (p, n))
    words = fused_pack_flat(u_buf, d_buf, rho, interpret=interpret)
    return fused_vote_update_words(words, v_buf, mask, mu,
                                   interpret=interpret)
