"""Elastic membership & straggler handling for the hierarchical mesh.

The paper's aggregation rules are natively elastic, and this module turns
that into runtime policy:

  * Cloud tier: w = sum_q (D_q/N) v_q -- the weights are *runtime inputs*
    to the compiled step, so pods joining/leaving between global rounds
    only require reweighting (no recompilation).  A lost pod's weight is
    renormalized over the survivors (``edge_weights``).
  * Edge tier: the majority vote takes a per-device ``vote mask``; a
    straggler or failed device simply abstains (Theorem 3's MAP argument
    holds for the reduced voter count).  ``quorum`` decides whether
    enough votes arrived to apply the step at all.

``Membership`` tracks liveness from heartbeats (simulated in tests by
fault injection) and produces the (edge_weights, dev_weights, mask)
triple every step.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Membership:
    pods: int
    devices_per_pod: int
    data_sizes: np.ndarray | None = None      # [P, D] |D_qk| (None = equal)
    quorum: float = 0.5                       # min live-vote fraction/edge
    heartbeat_timeout: float = 3.0

    def __post_init__(self):
        if self.data_sizes is None:
            self.data_sizes = np.ones((self.pods, self.devices_per_pod))
        self.live = np.ones((self.pods, self.devices_per_pod), bool)
        self.last_seen = np.zeros((self.pods, self.devices_per_pod))

    # -- liveness -----------------------------------------------------------
    def heartbeat(self, pod: int, dev: int, now: float):
        self.last_seen[pod, dev] = now
        self.live[pod, dev] = True

    def mark_failed(self, pod: int, dev: int | None = None):
        if dev is None:
            self.live[pod, :] = False
        else:
            self.live[pod, dev] = False

    def sweep(self, now: float):
        self.live &= (now - self.last_seen) <= self.heartbeat_timeout

    # -- weights ------------------------------------------------------------
    def pod_live(self) -> np.ndarray:
        """[P] -- a pod participates if it meets the vote quorum."""
        frac = self.live.mean(axis=1)
        return frac >= self.quorum

    def weights(self):
        """(edge_weights [P], dev_weights [P, D], vote_mask [P, D]).

        Failed devices lose their vote AND their anchor weight; failed
        pods lose their cloud-aggregation weight (renormalized).  All are
        plain float arrays fed to the already-compiled step.
        """
        mask = self.live.astype(np.float32)
        pod_ok = self.pod_live().astype(np.float32)
        if (pod_ok * mask.sum(axis=1)).sum() == 0:
            # fail-open: if no pod meets quorum the only alternative to
            # zeroing the model is to keep every voter counted; real
            # deployments alert here but must not destroy state.
            mask = np.ones_like(mask)
            pod_ok = np.ones_like(pod_ok)
        mask = mask * pod_ok[:, None]        # sub-quorum pod: all votes out
        d_eff = self.data_sizes * mask
        dq = d_eff.sum(axis=1)
        dev_w = np.where(dq[:, None] > 0, d_eff / np.maximum(
            dq[:, None], 1e-9), 0.0)
        pod_sizes = dq * pod_ok
        n = pod_sizes.sum()
        edge_w = pod_sizes / max(n, 1e-9)
        return (edge_w.astype(np.float32), dev_w.astype(np.float32),
                (mask * pod_ok[:, None]).astype(np.float32))
