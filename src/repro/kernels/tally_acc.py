"""Streamed-client tally accumulate: tally += w * sgn(g + rho*delta) (TPU).

The streamed virtual-client sweep (``ClientConfig.mode="stream"``,
``core.hier``) loops clients inside the step instead of widening the
voter axis: per client this kernel fuses the device-side compressor of
``sign_pack`` (gradient + stale correction -> sign bit) with the
edge-side weighted popcount of ``vote_update`` into ONE
read-modify-write of the persistent signed tally -- the client's sign
plane is never materialized in HBM, only the running tally (one int8/
int16/int32 per coordinate, dtype picked from the static weight bound
by ``core.votes.tally_dtype``) is live across the client loop.

The signed tally ``t = sum_c w_c * sgn(u_c) = 2*pos - n_eff`` defers the
sign threshold until after the loop (``core.votes.tally_vote``), where
``t >= 0`` reproduces the merged path's ``2*pos >= n_eff`` tie rule
exactly -- integer arithmetic, so the streamed trajectory is bitwise
identical to the merged-axis transports.

Tiling: grid over [R/BR, C/BC] like ``sign_pack``; per step the kernel
reads a (BR, BC) f32 block of g (+ the shared correction block via the
same slab-row BlockSpec trick) and read-modify-writes the (BR, BC)
tally block in place (aliased when compiled).  The per-voter weight
arrives as a [n_slabs, 1] int32 array indexed per row-block through its
BlockSpec -- no scalar re-tracing per client.

Single-device program: on multi-chip meshes it runs per-rank inside the
streamed fused transport's ``shard_map`` program (``core.votes``) on the
rank's model-axis bucket; the data-axis exchange happens once per local
step on the reduced tallies, not per client.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 64
BLOCK_C = 4096


def _tally_acc_kernel(g_ref, d_ref, w_ref, t_ref, o_ref, *, rho: float):
    g = g_ref[...].astype(jnp.float32)
    if d_ref is not None:
        g = g + rho * d_ref[...].astype(jnp.float32)
    s = jnp.where(g >= 0, jnp.int32(1), jnp.int32(-1))
    w = w_ref[0, 0]                                 # this slab's weight
    o_ref[...] = (t_ref[...].astype(jnp.int32) + w * s).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("rho", "block_r", "block_c",
                                    "interpret", "slab_rows"))
def tally_acc(g: jax.Array, delta: jax.Array | None, w: jax.Array,
              tally: jax.Array, *, rho: float = 0.0,
              block_r: int = BLOCK_R, block_c: int = BLOCK_C,
              interpret: bool = False,
              slab_rows: int | None = None) -> jax.Array:
    """g, tally: [R, C] (R % block_r == 0, C % block_c == 0); w:
    [R/slab_rows, 1] int32 per-voter weights (one weight per contiguous
    ``slab_rows``-row voter slab; ``slab_rows=None`` means one voter owns
    all R rows); delta: optional [R/replicas, C] shared correction,
    re-read per voter through its BlockSpec exactly like ``sign_pack``'s
    ``slab_rows`` path.  Returns the updated tally (int8/int16/int32),
    aliased over the input when compiled.
    """
    r, c = g.shape
    assert r % block_r == 0 and c % block_c == 0, (g.shape, block_r, block_c)
    assert tally.shape == (r, c), (tally.shape, g.shape)
    slab = r if slab_rows is None else slab_rows
    assert slab % block_r == 0 and r % slab == 0, (slab, block_r, r)
    rb = slab // block_r                   # row blocks per voter slab
    assert w.shape == (r // slab, 1), (w.shape, r, slab)
    grid = (r // block_r, c // block_c)

    in_specs = [pl.BlockSpec((block_r, block_c), lambda i, j: (i, j))]
    args = [g]
    if delta is not None:
        if delta.shape[0] == r:
            dmap = lambda i, j: (i, j)
        else:
            assert r % delta.shape[0] == 0, (r, delta.shape)
            reps = r // delta.shape[0]     # voters sharing each slab
            dmap = lambda i, j: ((i // (reps * rb)) * rb + i % rb, j)
        in_specs.append(pl.BlockSpec((block_r, block_c), dmap))
        args.append(delta)
        kernel = functools.partial(_tally_acc_kernel, rho=rho)
    else:
        kernel = functools.partial(
            lambda g_ref, w_ref, t_ref, o_ref, *, rho: _tally_acc_kernel(
                g_ref, None, w_ref, t_ref, o_ref, rho=rho), rho=rho)
    in_specs.append(pl.BlockSpec((1, 1), lambda i, j, _rb=rb: (i // _rb, 0)))
    args.append(w.astype(jnp.int32))
    in_specs.append(pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)))
    args.append(tally)

    # the tally aliases in place: a true read-modify-write (one HBM pass
    # over the tally when the caller donates it).  Interpret mode keeps
    # out-of-place semantics -- identical values either way.
    t_index = len(args) - 1
    alias = {} if interpret else {"input_output_aliases": {t_index: 0}}
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(tally.shape, tally.dtype),
        interpret=interpret,
        **alias,
    )(*args)
