"""Assigned-architecture registry: ``get_config(name)`` / ``get_smoke(name)``.

Each module defines CONFIG (the exact assigned configuration) and SMOKE (a
reduced same-family variant for CPU tests).  ``ARCH_NAMES`` is the assigned
10-arch pool; ``shape_applicable`` encodes the skip rules from DESIGN.md
Sec. 4 (long_500k only for sub-quadratic archs; decode only for archs with
a decoder).
"""
from __future__ import annotations

import importlib

from repro.models.config import LMConfig, SHAPES, ShapeCfg

ARCH_NAMES = [
    "arctic_480b",
    "deepseek_v3_671b",
    "whisper_base",
    "internvl2_76b",
    "stablelm_3b",
    "gemma3_12b",
    "gemma3_1b",
    "mistral_large_123b",
    "zamba2_2p7b",
    "xlstm_350m",
]

# accept dashed external ids too
_ALIASES = {n.replace("_", "-").replace("p", "."): n for n in ARCH_NAMES}


def _module(name: str):
    name = name.replace("-", "_").replace(".", "p")
    if name not in ARCH_NAMES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str) -> LMConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> LMConfig:
    return _module(name).SMOKE


def shape_applicable(cfg: LMConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.kind == "decode" and not cfg.has_decoder:
        return False, "encoder-only arch: no decode step"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: long_500k needs "
                       "sub-quadratic attention (DESIGN.md Sec. 4)")
    return True, ""


def all_cells():
    """Yield (arch_name, shape_name, applicable, reason) for all 40 cells."""
    for a in ARCH_NAMES:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            yield a, s.name, ok, why
