"""Scratch: engine fsdp regime == replicated regime on 8 host devices.

Uses a small dense config (divisible dims) and a small MoE config, flipped
between param_mode settings; trajectories must match bitwise.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# compare identical computation structures: the beyond-paper layout
# pinning perturbs f32 summation orders, which flips near-tied MoE
# router decisions and reroutes tokens -- a real (legitimate) numerical
# sensitivity of MoE + sign steps, but not what this equivalence test
# measures.
os.environ["REPRO_DISABLE_OPT"] = "1"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import hier
from repro.core.topology import Topology
from repro.models import build
from repro.models.config import LMConfig, MoECfg

Pn, Dn, Mn = 2, 2, 2
mesh = Mesh(np.array(jax.devices()).reshape(Pn, Dn, Mn),
            ("pod", "data", "model"))
topo = Topology(mesh=mesh, pod_axis="pod")

BASE = LMConfig(
    name="tiny-dense", family="dense", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=128, head_dim=16, remat=True)
MOE = LMConfig(
    name="tiny-moe", family="moe", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=64, vocab=128, head_dim=16,
    moe=MoECfg(n_experts=8, top_k=2, d_expert=64, capacity_factor=1.5,
               group_tokens=32), remat=True)

B_, T_ = 2, 16
for base_cfg in [BASE, MOE]:
    results = {}
    for mode in ["replicated", "fsdp"]:
        cfg = dataclasses.replace(base_cfg, param_mode=mode)
        built = build.build_model(cfg, topo)
        params = built.init_params(jax.random.PRNGKey(0))
        algo = hier.AlgoConfig(method="dc_hier_signsgd", mu=1e-3, t_e=2,
                               rho=1.0, compute_dtype=jnp.float32,
                               master_dtype=jnp.float32,
                               delta_dtype=jnp.float32)
        init_fn, step = hier.make_hier_step(topo, algo, built.bundle)
        state = init_fn(params, jax.random.PRNGKey(5))
        ew = jnp.full((Pn,), 0.5)
        dw = jnp.full((Pn, Dn), 0.5)
        mask = jnp.ones((Pn, Dn))
        jstep = jax.jit(step)
        for s in range(4):
            toks = jax.random.randint(jax.random.PRNGKey(100 + s),
                                      (Pn, Dn, B_, T_), 0, cfg.vocab)
            batch = {"train": {"tokens": toks}}
            state, m = jstep(state, batch, ew, dw, mask)
        results[mode] = (jax.tree.map(np.asarray, state.params),
                         float(m["loss"]))
        print(f"{cfg.name:10s} {mode:10s} loss={m['loss']:.4f}")
    pr, pf = results["replicated"][0], results["fsdp"][0]
    leaves_r = np.concatenate([np.asarray(a).ravel()
                               for a in jax.tree.leaves(pr)])
    leaves_f = np.concatenate([np.asarray(a).ravel()
                               for a in jax.tree.leaves(pf)])
    diff = np.abs(leaves_r - leaves_f)
    frac = (diff > 0).mean()
    print(f"{base_cfg.name}: max|repl-fsdp|={diff.max():.2e} "
          f"frac_differing={frac:.2e}")
    # sign methods amplify ULP noise to +-mu on near-zero-grad coords and
    # a flipped coordinate can compound over steps: require almost all
    # coords identical and drift bounded by 2*steps*mu
    assert frac < 1e-2, (base_cfg.name, frac)
    assert diff.max() <= 2 * 4 * 1e-3 + 1e-9, (base_cfg.name, diff.max())
print("ENGINE FSDP == REPLICATED OK")
