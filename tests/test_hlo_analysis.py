"""Per-axis collective accounting of benchmarks/hlo_analysis.py.

Regression for the double-counting bug: an UNATTRIBUTED collective
(unparsed replica_groups, or no axis_sizes) used to count toward EVERY
axis filter, inflating e.g. both the data-axis and model-axis all-gather
totals at once.  It now lands exactly once in the explicit
``unattributed`` bucket, and the strict ``assert_axis_free`` helper
refuses to pass a per-axis zero check while any of the op's bytes are
unattributed.
"""
import pytest

from benchmarks import hlo_analysis

# 2x2 (data, model) mesh: devices 0..3 = (d, m) row-major, so group
# {0,1} varies along 'model' and {0,2} along 'data'.  The second
# all-gather carries an unparsable replica_groups attribute.
HLO = """\
HloModule test

ENTRY %main (p0: f32[8,16]) -> f32[16,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %ag.model = f32[16,16]{1,0} all-gather(f32[8,16]{1,0} %p0), channel_id=1, replica_groups={{0,1},{2,3}}, dimensions={0}
  %ag.mystery = f32[16,16]{1,0} all-gather(f32[8,16]{1,0} %p0), channel_id=2, replica_groups=<opaque>, dimensions={0}
  ROOT %ar.data = f32[16,16]{1,0} all-reduce(f32[16,16]{1,0} %ag.model), channel_id=3, replica_groups={{0,2},{1,3}}, to_apply=%add
}
"""

AXES = {"data": 2, "model": 2}
P0_BYTES = 8 * 16 * 4
AG_OUT_BYTES = 16 * 16 * 4


@pytest.fixture(scope="module")
def stats():
    return hlo_analysis.analyze_hlo_text(HLO, axis_sizes=AXES)


def test_attributed_axes_label_correctly(stats):
    assert stats["per_axis_op_bytes"]["all-gather@model"] == P0_BYTES
    assert stats["per_axis_op_bytes"]["all-reduce@data"] == AG_OUT_BYTES


def test_unattributed_counts_once_not_per_axis(stats):
    cb = hlo_analysis.collective_bytes
    # the mystery gather lands ONLY in the unattributed bucket ...
    assert stats["per_axis_op_bytes"]["all-gather@unattributed"] == P0_BYTES
    assert cb(stats, op="all-gather", axis="unattributed") == P0_BYTES
    # ... and no longer inflates the named-axis filters
    assert cb(stats, op="all-gather", axis="model") == P0_BYTES
    assert cb(stats, op="all-gather", axis="data") == 0
    # unfiltered totals still see every byte exactly once
    assert cb(stats, op="all-gather") == 2 * P0_BYTES
    assert sum(stats["per_axis_bytes"].values()) == (
        2 * P0_BYTES + AG_OUT_BYTES)


def test_assert_axis_free_is_strict(stats):
    # attributed-zero + unattributed-zero for the op -> passes
    hlo_analysis.assert_axis_free(stats, op="all-reduce", axis="model")
    # data-axis all-gather bytes are 0, but the unattributed gather
    # could hide axis traffic: the strict check must fail, not pass
    # vacuously
    with pytest.raises(AssertionError, match="unattributed"):
        hlo_analysis.assert_axis_free(stats, op="all-gather", axis="data")
    with pytest.raises(AssertionError, match="model"):
        hlo_analysis.assert_axis_free(stats, op="all-gather", axis="model")


def test_no_axis_sizes_means_unattributed():
    stats = hlo_analysis.analyze_hlo_text(HLO, axis_sizes=None)
    keys = set(stats["per_axis_op_bytes"])
    assert keys == {"all-gather@unattributed", "all-reduce@unattributed"}
