"""End-to-end behaviour tests: the paper's phenomena in miniature.

Runs the full federated pipeline (Dirichlet-skewed data -> per-device
grads -> 1-bit votes -> edge models -> cloud aggregation) with the
paper's own MLP model and checks the headline claims of Sec. V."""
import numpy as np
import jax
import pytest

from repro.core import ref_fed, signs
from repro.data import emnist_like
from repro.models import mlp


def _train(method, rho, iid, rounds=8, t_e=15, batch=64, seed=0,
           mu=5e-3, mu_sgd=0.5):
    cfg = emnist_like.FedDataCfg(n_train=6000, n_test=1500, alpha=0.1,
                                 iid=iid, seed=seed, q_edges=4,
                                 devices_per_edge=3)
    dev, test, ew, dw = emnist_like.make_federated_data(cfg)
    rng = np.random.default_rng(seed)
    params = mlp.init_mlp(jax.random.PRNGKey(seed))
    state = ref_fed.init_state(params, cfg.q_edges)
    hcfg = ref_fed.HierConfig(mu=mu, mu_sgd=mu_sgd, t_e=t_e, rho=rho,
                              method=method)
    for t in range(rounds):
        batches = [[[emnist_like.device_batches(dev, q, k, batch, rng)
                     for _ in range(t_e)]
                    for k in range(cfg.devices_per_edge)]
                   for q in range(cfg.q_edges)]
        anchors = [[emnist_like.device_batches(dev, q, k, 4 * batch, rng)
                    for k in range(cfg.devices_per_edge)]
                   for q in range(cfg.q_edges)]
        state = ref_fed.global_round(state, hcfg, mlp.grad_fn, batches,
                                     anchors, ew, dw,
                                     jax.random.PRNGKey(1000 + t))
    return float(mlp.accuracy(state.w, test))


@pytest.mark.slow
def test_noniid_dc_beats_plain_sign():
    """Fig. 2 (non-IID): drift correction improves sign-based HFL."""
    acc_plain = _train("hier_signsgd", 0.0, iid=False)
    acc_dc = _train("dc_hier_signsgd", 0.2, iid=False)
    assert acc_dc > acc_plain + 0.02, (acc_plain, acc_dc)


@pytest.mark.slow
def test_noniid_dc_close_to_full_precision():
    """Fig. 2: DC-HierSignSGD ~ HierSGD at 1/32 the uplink."""
    acc_sgd = _train("hier_sgd", 0.0, iid=False)
    acc_dc = _train("dc_hier_signsgd", 0.2, iid=False)
    assert acc_dc > acc_sgd - 0.10, (acc_sgd, acc_dc)
    d = mlp.param_count(mlp.init_mlp(jax.random.PRNGKey(0)))
    assert (signs.uplink_bits("hier_sgd", d, 5)
            / signs.uplink_bits("hier_signsgd", d, 5)) == 32


@pytest.mark.slow
def test_iid_gap_small():
    """Fig. 2 (IID): corrected vs uncorrected gap shrinks."""
    acc_plain = _train("hier_signsgd", 0.0, iid=True)
    acc_dc = _train("dc_hier_signsgd", 0.2, iid=True)
    assert abs(acc_dc - acc_plain) < 0.08, (acc_plain, acc_dc)
