"""Shared test configuration.

Provides a minimal deterministic fallback for ``hypothesis`` when the real
package is not installed (hermetic CI images bake in only jax + pytest).
The stub implements exactly the subset the suite uses -- ``given``,
``settings`` and the ``integers`` / ``lists`` / ``sampled_from``
strategies -- drawing a fixed
number of pseudo-random examples from a per-test seeded numpy generator
(boundary values first), so property tests still execute and remain
reproducible.  When ``hypothesis`` IS importable, it is used unchanged.
"""
from __future__ import annotations

import sys
import types
import zlib


def _install_hypothesis_stub() -> None:
    import numpy as np

    class _Strategy:
        def __init__(self, draw, boundary=()):
            self._draw = draw
            self._boundary = tuple(boundary)

        def example_for(self, rng, index):
            if index < len(self._boundary):
                return self._boundary[index]
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            boundary=(min_value, max_value),
        )

    def sampled_from(values):
        vals = tuple(values)
        return _Strategy(
            lambda rng: vals[int(rng.integers(len(vals)))],
            boundary=vals,
        )

    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.example_for(rng, len(elements._boundary) + i)
                    for i in range(size)]

        small = [elements.example_for(np.random.default_rng(0), i)
                 for i in range(max(min_size, 1))]
        return _Strategy(draw, boundary=(small,) if min_size <= len(small)
                         else ())

    _DEFAULT_MAX_EXAMPLES = 20

    def given(*strategies):
        def deco(fn):
            def runner():
                n = getattr(runner, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for i in range(n):
                    args = [s.example_for(rng, i) for s in strategies]
                    try:
                        fn(*args)
                    except Exception as e:
                        raise AssertionError(
                            f"property falsified on example {i}: "
                            f"{fn.__name__}(*{args!r})") from e

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            runner.__qualname__ = fn.__qualname__
            return runner

        return deco

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.lists = lists
    st_mod.sampled_from = sampled_from

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    hyp_mod.__stub__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on the environment
    _install_hypothesis_stub()
