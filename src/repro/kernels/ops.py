"""Public jit'd wrappers around the Pallas kernels.

Handles arbitrary pytree/shape inputs (flatten -> pad -> 2D view ->
kernel -> unpad), and falls back to the jnp reference implementation when
Pallas is unavailable (CPU distributed paths use the reference; the
kernels are the TPU target, validated in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref, sign_pack as _sp, ternary_quant as _tq
from repro.kernels import vote_update as _vu

PACK = 32


def _to_2d(x: jax.Array, block_r: int, block_c: int):
    """Flatten + zero-pad to an [R, C] view divisible by the block."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    per_row = block_c
    rows = -(-n // per_row)
    rows = -(-rows // block_r) * block_r
    pad = rows * per_row - n
    flat = jnp.concatenate([flat, jnp.ones((pad,), flat.dtype)])
    return flat.reshape(rows, per_row), n


def sign_pack_nd(g: jax.Array, delta: jax.Array | None = None,
                 rho: float = 0.0, *, use_pallas: bool = True,
                 interpret: bool = True,
                 block_r: int = _sp.BLOCK_R, block_c: int = _sp.BLOCK_C):
    """Any-shape g (+delta) -> (packed [n_words] uint32, n_coords)."""
    g2, n = _to_2d(g, block_r, block_c)
    d2 = None
    if delta is not None:
        d2, _ = _to_2d(delta.astype(g.dtype), block_r, block_c)
    if use_pallas:
        packed = _sp.sign_pack(g2, d2, rho, block_r=block_r,
                               block_c=block_c, interpret=interpret)
    else:
        packed = ref.sign_pack_ref(g2, d2, rho)
    return packed.reshape(-1), n


def vote_update_nd(packed_rows: jax.Array, v: jax.Array,
                   mask: jax.Array | None = None, *, mu: float,
                   use_pallas: bool = True, interpret: bool = True,
                   block_r: int = _vu.BLOCK_R, block_c: int = _vu.BLOCK_C):
    """packed_rows: [K, n_words] (from sign_pack_nd on each device);
    v: any-shape model tensor.  Returns updated v."""
    k = packed_rows.shape[0]
    v2, n = _to_2d(v, block_r, block_c)
    r, c = v2.shape
    packed = packed_rows.reshape(k, r, c // PACK)
    if use_pallas:
        out = _vu.vote_update(packed, v2, mask, mu=mu, block_r=block_r,
                              block_c=block_c, interpret=interpret)
    else:
        out = ref.vote_update_ref(packed, v2, mu, mask)
    return out.reshape(-1)[:n].reshape(v.shape)


def ternary_quant_nd(x: jax.Array, rng: jax.Array, *,
                     use_pallas: bool = True, interpret: bool = True,
                     block_r: int = _tq.BLOCK_R, block_c: int = _tq.BLOCK_C):
    """Any-shape unbiased ternary quantization (baseline compressor)."""
    x2, n = _to_2d(x, block_r, block_c)
    # zero the padding so it cannot influence the norm
    flat = x2.reshape(-1).at[n:].set(0.0).reshape(x2.shape)
    norm = jnp.linalg.norm(flat.astype(jnp.float32))
    u = jax.random.uniform(rng, x2.shape, jnp.float32)
    if use_pallas:
        out = _tq.ternary_quant(flat, u, norm, block_r=block_r,
                                block_c=block_c, interpret=interpret)
    else:
        out = ref.ternary_quant_ref(flat, u, norm)
    return out.reshape(-1)[:n].reshape(x.shape)
