"""Fused edge-side vote + model update: v' = v - mu * MajorityVote(packed).

The edge server holds K one-bit uplink payloads (packed uint32 rows, one
per device) and the edge model v.  This kernel unpacks the K bit-planes,
popcount-votes per coordinate (ties -> +1, abstaining voters masked), and
applies the sign-descent update in a single read-modify-write of v --
one HBM pass over the model instead of three (unpack, vote, update).

The voter ``mask`` generalizes to nonnegative integer vote weights (the
``core.clients`` data shares |D_qk|): each bit-plane is scaled by its
weight in the int32 tally, the tie rule compares against the
participating weight sum, and an edge whose whole quorum abstains (all
weights 0) votes 0 -- the read-modify-write then leaves v unchanged.

Tiling: grid over [R/BR, C/BC]; per step the kernel reads a (K, BR, BC/32)
uint32 slab + a (BR, BC) f32 block of v (VMEM ~2 MB at K=16).

Single-device program: on multi-chip meshes it runs per-rank inside the
fused transport's ``shard_map`` program (``core.votes``) on the rank's
model-axis bucket of the flat buffer, consuming the K uplink payloads
gathered over the data axis -- the vote never sees (and the mesh never
materializes) an unsharded bit tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PACK = 32
BLOCK_R = 64
BLOCK_C = 4096


def _vote_update_kernel(p_ref, v_ref, m_ref, o_ref, *, mu: float,
                        n_voters: int):
    words = p_ref[...]                              # [K, BR, BC/32] uint32
    k, br, wpb = words.shape
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = ((words[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    if m_ref is not None:
        m = m_ref[...].astype(jnp.int32)            # [K] mask or weights
        pos = jnp.sum(bits * m[:, None, None, None], axis=0)
        n_eff = jnp.sum(m)
    else:
        pos = jnp.sum(bits, axis=0)                 # [BR, BC/32, 32]
        n_eff = n_voters
    vote = jnp.where(2 * pos >= n_eff, 1.0, -1.0).astype(jnp.float32)
    if m_ref is not None:   # empty quorum abstains: v is left unchanged
        vote = jnp.where(n_eff > 0, vote, 0.0).astype(jnp.float32)
    vote = vote.reshape(br, wpb * PACK)
    o_ref[...] = (v_ref[...].astype(jnp.float32) - mu * vote
                  ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("mu", "block_r", "block_c", "interpret"))
def vote_update(packed: jax.Array, v: jax.Array,
                mask: jax.Array | None = None, *, mu: float,
                block_r: int = BLOCK_R, block_c: int = BLOCK_C,
                interpret: bool = False) -> jax.Array:
    """packed: [K, R, C/32] uint32; v: [R, C] float; mask: [K] or None."""
    k, r, w = packed.shape
    c = v.shape[-1]
    assert w * PACK == c and v.shape == (r, c)
    assert r % block_r == 0 and c % block_c == 0
    grid = (r // block_r, c // block_c)
    wpb = block_c // PACK

    in_specs = [
        pl.BlockSpec((k, block_r, wpb), lambda i, j: (0, i, j)),
        pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
    ]
    args = [packed, v]
    if mask is not None:
        in_specs.append(pl.BlockSpec((k,), lambda i, j: (0,)))
        args.append(mask.astype(jnp.int32))
        kernel = functools.partial(_vote_update_kernel, mu=mu, n_voters=k)
    else:
        kernel = functools.partial(
            lambda p_ref, v_ref, o_ref, *, mu, n_voters: _vote_update_kernel(
                p_ref, v_ref, None, o_ref, mu=mu, n_voters=n_voters),
            mu=mu, n_voters=k)

    # v' aliases v: the kernel is a true read-modify-write (one HBM pass
    # over the model when the caller donates v).  Interpret mode keeps
    # out-of-place semantics -- identical values either way.
    alias = {} if interpret else {"input_output_aliases": {1: 0}}
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(v.shape, v.dtype),
        interpret=interpret,
        **alias,
    )(*args)
