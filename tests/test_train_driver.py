"""Integration: the end-to-end driver trains, checkpoints, resumes, and
survives injected faults (device loss -> quorum vote; elastic reweight)."""
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import hier
from repro.core.topology import single_device_topology
from repro.launch.train import RunCfg, run_training
from repro.runtime import failures


def _algo(**kw):
    base = dict(method="dc_hier_signsgd", mu=2e-3, rho=0.3, t_e=4,
                compute_dtype=jnp.float32)
    base.update(kw)
    return hier.AlgoConfig(**base)


@pytest.fixture(scope="module")
def topo():
    return single_device_topology()


@pytest.mark.slow
def test_training_reduces_loss(topo):
    cfg = configs.get_smoke("stablelm_3b")
    _, hist = run_training(cfg, topo, _algo(), RunCfg(
        steps=24, batch_per_device=8, seq_len=64, log_every=0))
    first = sum(h["loss"] for h in hist[:4]) / 4
    last = sum(h["loss"] for h in hist[-4:]) / 4
    assert last < first, (first, last)


@pytest.mark.slow
def test_checkpoint_resume_continues(topo, tmp_path):
    cfg = configs.get_smoke("xlstm_350m")
    run = RunCfg(steps=10, batch_per_device=4, seq_len=32,
                 ckpt_dir=str(tmp_path), ckpt_every=5, log_every=0)
    _, h1 = run_training(cfg, topo, _algo(), run)
    run2 = RunCfg(steps=14, batch_per_device=4, seq_len=32,
                  ckpt_dir=str(tmp_path), ckpt_every=5, log_every=0)
    _, h2 = run_training(cfg, topo, _algo(), run2)
    # resumed run starts where the first left off
    assert h2[0]["step"] == 10
    assert all(x["loss"] == y["loss"] for x, y in zip(h1, h1))


@pytest.mark.slow
def test_flat_state_resumes_from_tree_checkpoint(topo, tmp_path):
    """Cross-layout resume: a tree-state run's checkpoint loads into a
    state_layout='flat' run (store converts leaves into the buffer) and
    training continues from the same step."""
    cfg = configs.get_smoke("xlstm_350m")
    run = RunCfg(steps=10, batch_per_device=4, seq_len=32,
                 ckpt_dir=str(tmp_path), ckpt_every=5, log_every=0)
    run_training(cfg, topo, _algo(), run)
    run2 = RunCfg(steps=14, batch_per_device=4, seq_len=32,
                  ckpt_dir=str(tmp_path), ckpt_every=5, log_every=0)
    _, h2 = run_training(cfg, topo,
                         _algo(state_layout="flat", transport="fused"),
                         run2)
    assert h2[0]["step"] == 10
    assert all(jnp.isfinite(h["loss"]) for h in h2)


@pytest.mark.slow
def test_fault_injection_device_loss(topo):
    """Losing a device mid-run degrades to quorum voting, not a crash."""
    cfg = configs.get_smoke("gemma3_1b")
    inj = failures.FaultInjector({6: ("device", 0, 0),
                                  9: ("recover", 0, 0)})
    _, hist = run_training(cfg, topo, _algo(), RunCfg(
        steps=12, batch_per_device=4, seq_len=32, log_every=0),
        fault_injector=inj)
    assert len(hist) == 12
    assert all(jnp.isfinite(h["loss"]) for h in hist)
    # membership dipped during the outage and recovered
    assert min(h["live"] for h in hist) < 1.0
    assert hist[-1]["live"] == 1.0


@pytest.mark.slow
def test_overlap_driver_resumes_mid_flight(topo, tmp_path):
    """The end-to-end driver runs the overlapped cloud schedule and
    resumes from a checkpoint taken MID-round (t_e=4, ckpt_every=5:
    step 10 is two local steps into a round, with an aggregate staged
    in agg_next) -- the staged slot rides the async checkpoint path."""
    cfg = configs.get_smoke("xlstm_350m")
    algo = _algo(cloud_overlap="overlap")
    run = RunCfg(steps=10, batch_per_device=4, seq_len=32,
                 ckpt_dir=str(tmp_path), ckpt_every=5, log_every=0)
    _, h1 = run_training(cfg, topo, algo, run)
    run2 = RunCfg(steps=14, batch_per_device=4, seq_len=32,
                  ckpt_dir=str(tmp_path), ckpt_every=5, log_every=0)
    _, h2 = run_training(cfg, topo, algo, run2)
    assert h2[0]["step"] == 10
    assert all(jnp.isfinite(h["loss"]) for h in h1 + h2)


def test_cli_rejects_overlap_on_fsdp_arch():
    """--cloud_overlap=overlap on an FSDP arch is rejected at the CLI
    (exit 2, readable argparse error) BEFORE any model build or
    tracing."""
    import pathlib
    import subprocess
    import sys
    src = pathlib.Path(__file__).resolve().parents[1] / "src"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "gemma3_12b", "--cloud_overlap", "overlap", "--steps", "1"],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": str(src), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"})
    assert r.returncode == 2, (r.returncode, r.stderr[-2000:])
    assert "replicated regime" in r.stderr
    assert "--cloud_overlap" in r.stderr
    assert "Traceback" not in r.stderr


def test_cli_rejects_bad_client_carve():
    """A per-device batch that does not divide into --clients_per_device
    is rejected at the CLI (exit 2, readable argparse error) BEFORE any
    model build or tracing -- not a mid-trace shape error."""
    import pathlib
    import subprocess
    import sys
    src = pathlib.Path(__file__).resolve().parents[1] / "src"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--smoke",
         "--batch", "5", "--clients_per_device", "4", "--steps", "1"],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": str(src), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"})
    assert r.returncode == 2, (r.returncode, r.stderr[-2000:])
    assert "does not divide into" in r.stderr
    assert "--clients_per_device" in r.stderr
    assert "Traceback" not in r.stderr
