"""whisper-base [audio]: enc-dec, 6L+6L d512 8H ff2048 v51865; conv
frontend is a STUB (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]
"""
import dataclasses

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="whisper-base", family="encdec",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=51865, head_dim=64, act="gelu",
    encoder_layers=6, encoder_frames=1500, frontend_dim=80,
    param_mode="replicated", supports_long_context=False,
)

SMOKE = dataclasses.replace(
    CONFIG, name="whisper-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
    encoder_layers=2, encoder_frames=32, frontend_dim=16,
)
