"""Fault-tolerant checkpoint store (atomic, integrity-checked, keep-k).

Layout per checkpoint:
    <dir>/step_<N>.tmp-<pid>/   (written)   ->  <dir>/step_<N>/  (renamed)
        manifest.json           {step, tree structure, per-file crc32}
        arrays.npz              flat leaves (key = leaf path)
    <dir>/LATEST                text file with the newest complete step

Atomicity: everything is written into a tmp dir and os.rename'd into
place (POSIX-atomic), LATEST updated last; a crash mid-write can never
corrupt an existing checkpoint.  ``restore_latest`` verifies CRCs and
falls back to the previous checkpoint if the newest is damaged --
together with the driver's retry loop this is the node-failure story
(DESIGN.md Sec. 7).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
SEP = "/"


def _is_prng_key(x) -> bool:
    try:
        return jnp.issubdtype(x.dtype, jax.dtypes.prng_key)
    except Exception:
        return False


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if _is_prng_key(leaf):
            leaf = jax.random.key_data(leaf)   # typed key -> uint32 payload
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":              # bfloat16: no numpy dtype --
            arr = np.asarray(jnp.asarray(leaf).astype(jnp.float32))
        out[key] = arr                         # restore casts back
    return out, treedef


def save(ckpt_dir: str | pathlib.Path, step: int, tree: PyTree,
         keep: int = 3) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:010d}"
    tmp = ckpt_dir / f"step_{step:010d}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    arrays, _ = _flatten(tree)
    npz_path = tmp / "arrays.npz"
    np.savez(npz_path, **arrays)
    crc = zlib.crc32(npz_path.read_bytes())
    manifest = {
        "step": step,
        "crc32": crc,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    (ckpt_dir / "LATEST.tmp").write_text(str(step))
    os.rename(ckpt_dir / "LATEST.tmp", ckpt_dir / "LATEST")
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: pathlib.Path, keep: int):
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob(
        "step_*") if p.is_dir() and ".tmp" not in p.name)
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:010d}", ignore_errors=True)


def available_steps(ckpt_dir: str | pathlib.Path) -> list[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    return sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob(
        "step_*") if p.is_dir() and ".tmp" not in p.name)


def _verify(path: pathlib.Path) -> bool:
    try:
        manifest = json.loads((path / "manifest.json").read_text())
        crc = zlib.crc32((path / "arrays.npz").read_bytes())
        return crc == manifest["crc32"]
    except Exception:
        return False


def restore(ckpt_dir: str | pathlib.Path, step: int,
            like: PyTree) -> PyTree:
    """Restore into the structure (and shardings) of ``like``."""
    path = pathlib.Path(ckpt_dir) / f"step_{step:010d}"
    if not _verify(path):
        raise IOError(f"checkpoint {path} failed integrity check")
    data = np.load(path / "arrays.npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = SEP.join(str(getattr(x, "key", getattr(x, "idx", x)))
                       for x in p)
        arr = data[key]
        if _is_prng_key(leaf):
            arr = jax.random.wrap_key_data(jnp.asarray(arr))
        elif hasattr(leaf, "sharding") and hasattr(leaf, "dtype"):
            arr = jax.device_put(jnp.asarray(arr).astype(leaf.dtype),
                                 leaf.sharding)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_latest(ckpt_dir: str | pathlib.Path, like: PyTree
                   ) -> tuple[int, PyTree] | None:
    """Newest intact checkpoint (skipping corrupted ones), or None."""
    for step in reversed(available_steps(ckpt_dir)):
        path = pathlib.Path(ckpt_dir) / f"step_{step:010d}"
        if _verify(path):
            return step, restore(ckpt_dir, step, like)
    return None
