"""Property suite for the intra-edge heterogeneity axis and the
cluster-aware edge assignment (``data.cluster``).

Pins the contracts the scenario layer promises:

  * ``alpha_client=None`` / ``inf`` is BITWISE the legacy split (data
    modules gate the new code path entirely);
  * per-client sample counts conserve the edge totals |D_q| and the
    fleet total N (the intra-edge split moves samples between an edge's
    devices, never across edges);
  * the largest-remainder apportionment replaces the floor split that
    dumped all rounding residue on the last bucket;
  * clustering is deterministic across global seed state and process
    restarts, invariant to client permutation, and balanced;
  * signatures never leak raw samples -- only label histograms /
    aggregated sketches cross the device->server tier boundary.
"""
import dataclasses
import pathlib
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import cluster, emnist_like, synthetic

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


def _cfg(seed, **kw):
    return emnist_like.FedDataCfg(n_train=420, n_test=60, q_edges=3,
                                  devices_per_edge=4, seed=seed, **kw)


def _flat(device_data):
    return [d for edge in device_data for d in edge]


# ---------------------------------------------------------------------------
# alpha_client=None / inf == legacy, bitwise
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_alpha_client_none_and_inf_bitwise_legacy(seed):
    """Dirichlet(inf) is conceptually the IID split, so None and inf
    must take the exact legacy code path -- every device's (x, y) is
    bitwise identical, as are the weights."""
    a, ta, ewa, dwa = emnist_like.make_federated_data(_cfg(seed))
    b, tb, ewb, dwb = emnist_like.make_federated_data(
        _cfg(seed, alpha_client=float("inf")))
    for da, db in zip(_flat(a), _flat(b)):
        np.testing.assert_array_equal(da["x"], db["x"])
        np.testing.assert_array_equal(da["y"], db["y"])
    assert ewa == ewb and dwa == dwb
    np.testing.assert_array_equal(ta["x"], tb["x"])


def test_stream_alpha_client_inf_bitwise_legacy():
    """Same gate on the LM stream: None and inf emit bitwise-identical
    token batches (the per-client sampling path never engages)."""
    base = synthetic.LMStreamCfg(vocab=40, seq_len=6, batch_per_device=8,
                                 pods=2, devices_per_pod=2,
                                 clients_per_device=2)
    inf = dataclasses.replace(base, alpha_client=float("inf"))
    s0, s1 = synthetic.make_stream(base), synthetic.make_stream(inf)
    for step in (0, 3, 17):
        np.testing.assert_array_equal(np.asarray(s0(step)["tokens"]),
                                      np.asarray(s1(step)["tokens"]))


# ---------------------------------------------------------------------------
# sample-count conservation under the intra-edge split
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([0.05, 0.3, 1.0, 8.0]))
def test_per_client_counts_sum_to_edge_totals(seed, alpha_client):
    """The intra-edge Dirichlet split redistributes each edge's samples
    across ITS devices: per-edge totals |D_q| equal the legacy split's
    (the edge assignment upstream is untouched) and the fleet total is
    exactly N -- no sample is dropped or duplicated."""
    legacy, *_ = emnist_like.make_federated_data(_cfg(seed))
    skewed, *_ = emnist_like.make_federated_data(
        _cfg(seed, alpha_client=alpha_client))
    legacy_tot = [sum(len(d["y"]) for d in e) for e in legacy]
    skew_tot = [sum(len(d["y"]) for d in e) for e in skewed]
    assert skew_tot == legacy_tot
    assert sum(skew_tot) == 420
    for e in skewed:
        for d in e:
            assert len(d["y"]) == len(d["x"])


def test_alpha_client_actually_skews():
    """Guard: a small alpha_client produces devices whose label
    histograms differ within one edge (the axis is live)."""
    dd, *_ = emnist_like.make_federated_data(_cfg(0, alpha_client=0.05))
    sigs = cluster.label_histogram_signatures(dd, 10)
    per_edge = sigs.reshape(3, 4, 10)
    spread = np.mean(np.sum(
        (per_edge - per_edge.mean(axis=1, keepdims=True)) ** 2, axis=-1))
    assert spread > 0.05, spread


# ---------------------------------------------------------------------------
# largest-remainder apportionment (the floor-split fix)
# ---------------------------------------------------------------------------


def test_largest_remainder_regression_uniform():
    """Regression for the floor split: uniform 1/7 of 10 items used to
    give the last bucket 4 (floor residue) -- largest remainder spreads
    the residue, max-min <= 1."""
    c = cluster.largest_remainder(np.full(7, 1 / 7), 10)
    assert c.sum() == 10
    assert c.max() - c.min() <= 1, c


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 9), st.integers(0, 200))
def test_largest_remainder_properties(seed, buckets, n):
    """Counts are nonnegative ints summing exactly to n, and each count
    is within one of its real-valued quota (the defining property of
    largest-remainder apportionment)."""
    p = np.random.default_rng(seed).dirichlet(np.full(buckets, 0.2))
    c = cluster.largest_remainder(p, n)
    quota = p / p.sum() * n
    assert c.sum() == n and (c >= 0).all()
    assert np.all(c >= np.floor(quota) - 1e-9), (c, quota)
    assert np.all(c <= np.ceil(quota) + 1e-9), (c, quota)


# ---------------------------------------------------------------------------
# clustering: deterministic, restart-stable, permutation-invariant
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 3, 4]))
def test_clustering_deterministic_and_permutation_invariant(seed, n_edges):
    """Same signature multiset -> same balanced assignment, including
    the edge LABELS, no matter how the clients are ordered."""
    rng = np.random.default_rng(seed)
    n = n_edges * int(rng.integers(2, 5))
    sigs = rng.dirichlet(np.full(6, 0.3), size=n)
    a1 = cluster.cluster_edges(sigs, n_edges)
    np.testing.assert_array_equal(a1, cluster.cluster_edges(sigs.copy(),
                                                            n_edges))
    perm = rng.permutation(n)
    np.testing.assert_array_equal(a1[perm],
                                  cluster.cluster_edges(sigs[perm],
                                                        n_edges))
    counts = [int((a1 == q).sum()) for q in range(n_edges)]
    assert counts == [n // n_edges] * n_edges, counts


def test_clustering_ignores_global_seed_state():
    """The clustering consumes NO randomness at all (the determinism
    contract mirrors the splitmix32 participation scheme): global numpy
    seed state cannot change the assignment."""
    sigs = np.random.default_rng(7).dirichlet(np.full(4, 0.5), size=8)
    np.random.seed(0)
    a = cluster.cluster_edges(sigs, 2)
    np.random.seed(12345)
    np.testing.assert_array_equal(a, cluster.cluster_edges(sigs, 2))


def test_clustering_deterministic_across_process_restarts(tmp_path):
    """A fresh interpreter re-clustering the same signatures lands on
    the identical assignment (no hash-seed / import-order sensitivity)."""
    code = (
        "import numpy as np\n"
        "from repro.data import cluster\n"
        "sigs = np.random.default_rng(1234).dirichlet("
        "np.full(5, 0.25), size=12)\n"
        "print(','.join(map(str, cluster.cluster_edges(sigs, 3))))\n")
    env = {"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
           "HOME": str(tmp_path), "PYTHONHASHSEED": "random"}
    outs = set()
    for _ in range(2):
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, env=env,
                           timeout=120)
        assert r.returncode == 0, r.stderr[-2000:]
        outs.add(r.stdout.strip())
    sigs = np.random.default_rng(1234).dirichlet(np.full(5, 0.25), size=12)
    here = ",".join(map(str, cluster.cluster_edges(sigs, 3)))
    assert outs == {here}, (outs, here)


# ---------------------------------------------------------------------------
# signatures never leak raw samples
# ---------------------------------------------------------------------------


class _Poison:
    """Stands in for raw feature rows: raises on ANY read."""

    def _trip(self, *a, **k):
        raise AssertionError("raw samples crossed the tier boundary")

    __array__ = __iter__ = __getitem__ = __len__ = _trip


def test_signatures_never_touch_raw_samples():
    """The clustered assignment must work end-to-end with the feature
    rows replaced by poison objects: only label HISTOGRAMS feed the
    clustering, and LM-side sketches take already-aggregated vectors."""
    rng = np.random.default_rng(3)
    device_data = [[{"x": _Poison(), "y": rng.integers(0, 5, size=20)}
                    for _ in range(3)] for _ in range(2)]
    sigs = cluster.label_histogram_signatures(device_data, 5)
    assert sigs.shape == (6, 5)
    np.testing.assert_allclose(sigs.sum(axis=1), 1.0)
    assert len(cluster.cluster_edges(sigs, 2)) == 6
    sk = cluster.sketch_signatures(rng.normal(size=(6, 7)))
    assert sk.shape == (6, 7)
    np.testing.assert_allclose(np.linalg.norm(sk, axis=1), 1.0)


# ---------------------------------------------------------------------------
# edge assignment modes
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 1000), st.sampled_from(["random", "clustered"]))
def test_edge_assignment_permutes_clients(seed, mode):
    """random/clustered regrouping is a pure client permutation: the
    multiset of device datasets is unchanged, each edge keeps exactly
    devices_per_edge slots, and the weights renormalize per new edge."""
    base, *_ = emnist_like.make_federated_data(_cfg(seed,
                                                    alpha_client=0.2))
    moved, _, ew, dw = emnist_like.make_federated_data(
        _cfg(seed, alpha_client=0.2, edge_assign=mode))
    key = lambda d: (d["y"].tobytes(), d["x"].tobytes())
    assert sorted(map(key, _flat(base))) == sorted(map(key, _flat(moved)))
    assert all(len(e) == 4 for e in moved)
    assert abs(sum(ew) - 1.0) < 1e-9
    for q in range(3):
        if sum(len(d["y"]) for d in moved[q]):
            assert abs(sum(dw[q]) - 1.0) < 1e-9


def test_clustered_edges_more_homogeneous_than_random():
    """The point of the clustered mode: regrouping by label-histogram
    similarity leaves each edge internally MORE homogeneous (smaller
    within-edge signature spread) than a random scatter of the same
    clients."""

    def spread(mode):
        dd, *_ = emnist_like.make_federated_data(
            _cfg(0, alpha_client=0.1, edge_assign=mode))
        sigs = cluster.label_histogram_signatures(dd, 10).reshape(3, 4, 10)
        return float(np.mean(np.sum(
            (sigs - sigs.mean(axis=1, keepdims=True)) ** 2, axis=-1)))

    assert spread("clustered") < spread("random"), (
        spread("clustered"), spread("random"))


def test_stream_clients_distinct_distributions():
    """With alpha_client active, the carve's row blocks stream from
    genuinely distinct unigram distributions (large total-variation
    distance between the two clients of one slice)."""
    cfg = synthetic.LMStreamCfg(vocab=30, seq_len=64, batch_per_device=32,
                                pods=1, devices_per_pod=1,
                                clients_per_device=2, alpha_client=0.1)
    toks = np.asarray(synthetic.make_stream(cfg)(0)["tokens"])[0, 0]
    h0 = np.bincount(toks[:16].ravel(), minlength=30).astype(float)
    h1 = np.bincount(toks[16:].ravel(), minlength=30).astype(float)
    tv = 0.5 * np.abs(h0 / h0.sum() - h1 / h1.sum()).sum()
    assert tv > 0.2, tv


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_validation_rejects_bad_axes():
    with pytest.raises(ValueError, match="edge_assign"):
        emnist_like.make_federated_data(_cfg(0, edge_assign="bogus"))
    with pytest.raises(ValueError, match="alpha_client"):
        emnist_like.make_federated_data(_cfg(0, alpha_client=-1.0))
    base = dict(vocab=16, seq_len=4, batch_per_device=8, pods=2,
                devices_per_pod=2)
    with pytest.raises(ValueError, match="edge_assign"):
        synthetic.make_stream(synthetic.LMStreamCfg(**base,
                                                    edge_assign="bogus"))
    # clustered needs the client carve active AND intra-edge skew
    with pytest.raises(ValueError, match="clients_per_device"):
        synthetic.make_stream(synthetic.LMStreamCfg(
            **base, edge_assign="clustered"))
    with pytest.raises(ValueError, match="alpha_client"):
        synthetic.make_stream(synthetic.LMStreamCfg(
            **base, clients_per_device=2, edge_assign="clustered"))
    with pytest.raises(ValueError, match="equal edges"):
        cluster.cluster_edges(np.eye(4), 3)
    with pytest.raises(ValueError, match="balanced"):
        cluster.assignment_order(np.array([0, 0, 0, 1]), 2)
