"""xlstm-350m [ssm]: 24 blocks d1024, 4 heads, 7:1 mLSTM:sLSTM, d_ff=0
(feed-forward lives in the mLSTM up/down projections).
[arXiv:2405.04517; unverified]
"""
import dataclasses

from repro.models.config import LMConfig, XLSTMCfg

CONFIG = LMConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304,
    xlstm=XLSTMCfg(m_per_s=7, proj_factor=2.0, conv_kernel=4),
    param_mode="replicated", supports_long_context=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="xlstm-smoke", n_layers=8, d_model=64, n_heads=4,
    n_kv_heads=4, vocab=256,
    xlstm=XLSTMCfg(m_per_s=3, proj_factor=2.0, conv_kernel=4),
)
