"""Property suite for the extended ``ref_fed`` oracle: virtual clients,
per-round participation masks and weighted majority votes.

The oracle is the ground truth of the whole repo, so its new semantics
are pinned here *independently* of the distributed implementation:

  * unit-weight full-participation arguments reproduce the legacy
    oracle BITWISE (the migration safety net at the oracle level);
  * the weighted vote is invariant to permuting the clients within an
    edge (integer tallies are exactly commutative);
  * a round in which every client is masked out leaves ``v_q``
    unchanged (the empty quorum abstains -- vote 0);
  * weighted ties follow the documented ``sgn(0) = +1`` convention.

Plus the pinned participation-sampling scheme of ``core.clients``: the
mask of round t is a pure function of (seed, t) -- identical across
transports, state layouts and the step-within-round, so a checkpoint
restored mid-round resamples the identical quorum.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import clients as vclients
from repro.core import ref_fed, signs

DIM = 6


def _grad_fn(targets):
    """Deterministic linear grads g_k = w - target_k (rng unused), so
    trajectories are exactly reproducible and permutation properties
    are well-defined."""
    def grad_fn(params, batch, rng):
        return {"w": params["w"] - targets[batch["k"]]}
    return grad_fn


def _targets(n, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, DIM)).astype(np.float32))


def _round(n_clients, seed, method="hier_signsgd", **kw):
    """One oracle round over a single edge with n_clients clients."""
    targets = _targets(n_clients, seed)
    cfg = ref_fed.HierConfig(mu=1e-2, t_e=3, rho=1.0, method=method)
    state = ref_fed.init_state({"w": jnp.zeros(DIM)}, 1)
    batches = [[[{"k": k} for _ in range(cfg.t_e)]
                for k in range(n_clients)]]
    anchors = [[{"k": k} for k in range(n_clients)]]
    dw = kw.pop("device_weights", [[1.0 / n_clients] * n_clients])
    state = ref_fed.global_round(
        state, cfg, _grad_fn(targets), batches, anchors, [1.0], dw,
        jax.random.PRNGKey(0), **kw)
    return np.asarray(state.w["w"])


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(0, 5),
       st.sampled_from(["hier_signsgd", "dc_hier_signsgd", "hier_sgd"]))
def test_unit_full_participation_equals_legacy_oracle(n, seed, method):
    """Unit weights + full participation through the NEW argument
    surface is bitwise the legacy oracle call."""
    legacy = _round(n, seed, method)
    grown = _round(
        n, seed, method,
        device_mask=[[True] * n],
        vote_weights=[[1] * n],
        device_weights=[[1.0 / n] * n],
        reweight_participation=True)
    np.testing.assert_array_equal(legacy, grown)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(0, 7))
def test_vote_invariant_to_client_permutation(n, seed):
    """Permuting the clients of an edge (batches, weights, mask
    together) cannot change the weighted vote: integer tallies are
    exactly commutative."""
    rng = np.random.default_rng(seed + 100)
    perm = rng.permutation(n)
    weights = [int(w) for w in rng.integers(1, 6, n)]
    mask = [bool(b) for b in rng.integers(0, 2, n)]
    targets = _targets(n, seed)

    def run(order):
        cfg = ref_fed.HierConfig(mu=1e-2, t_e=3, method="hier_signsgd")
        state = ref_fed.init_state({"w": jnp.zeros(DIM)}, 1)
        batches = [[[{"k": int(k)} for _ in range(cfg.t_e)]
                    for k in order]]
        anchors = [[{"k": int(k)} for k in order]]
        state = ref_fed.global_round(
            state, cfg, _grad_fn(targets), batches, anchors, [1.0],
            [[1.0 * weights[k] for k in order]], jax.random.PRNGKey(0),
            device_mask=[[mask[k] for k in order]],
            vote_weights=[[weights[k] for k in order]],
            reweight_participation=True)
        return np.asarray(state.w["w"])

    np.testing.assert_array_equal(run(range(n)), run(perm))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(0, 5),
       st.sampled_from(["hier_signsgd", "dc_hier_signsgd"]))
def test_all_clients_masked_round_is_identity(n, seed, method):
    """An edge whose whole quorum abstains takes NO local steps: the
    empty vote is 0, so v_q (here: the single-edge w) is unchanged."""
    got = _round(n, seed, method,
                 device_mask=[[False] * n],
                 vote_weights=[[1] * n],
                 reweight_participation=True)
    np.testing.assert_array_equal(got, np.zeros(DIM, np.float32))


def test_weighted_ties_follow_sgn_zero_convention():
    """Weighted tallies that cancel exactly vote +1 (sgn(0) = +1); a
    quorum of weight zero abstains (vote 0) instead."""
    s = jnp.asarray([[1], [-1], [-1]], jnp.int8)        # 3 voters, 1 coord
    # 2*(+1) + 1*(-1) + 1*(-1) = 0 -> tie -> +1
    assert int(signs.majority_vote(s, jnp.asarray([2, 1, 1]))[0]) == 1
    # 1*(+1) + 3*(-1) + 0*(-1) = -2 -> -1 (masked voter carries no weight)
    assert int(signs.majority_vote(s, jnp.asarray([1, 3, 0]))[0]) == -1
    # empty quorum -> abstain
    assert int(signs.majority_vote(s, jnp.asarray([0, 0, 0]))[0]) == 0
    # same conventions through the packed bit-plane path
    words = signs.pack_signs(s.reshape(3, 1, 1).repeat(32, axis=2)
                             .reshape(3, 32))
    np.testing.assert_array_equal(
        np.asarray(signs.majority_vote_packed(words, 32,
                                              jnp.asarray([2, 1, 1]))), 1)
    np.testing.assert_array_equal(
        np.asarray(signs.majority_vote_packed(words, 32,
                                              jnp.asarray([0, 0, 0]))), 0)
    # and through a full oracle round: two equal-weight clients with
    # opposite gradient signs tie every coordinate -> vote +1 -> w
    # moves by exactly -mu per step
    targets = jnp.stack([jnp.full((DIM,), 1.0), jnp.full((DIM,), -1.0)])
    cfg = ref_fed.HierConfig(mu=1e-2, t_e=1, method="hier_signsgd")
    state = ref_fed.init_state({"w": jnp.zeros(DIM)}, 1)
    state = ref_fed.global_round(
        state, cfg, _grad_fn(targets), [[[{"k": 0}], [{"k": 1}]]],
        [[{"k": 0}, {"k": 1}]], [1.0], [[0.5, 0.5]],
        jax.random.PRNGKey(0), device_mask=[[True, True]],
        vote_weights=[[3, 3]], reweight_participation=True)
    np.testing.assert_allclose(np.asarray(state.w["w"]),
                               np.full(DIM, -1e-2), rtol=1e-6)


# ---------------------------------------------------------------------------
# Pinned participation sampling (core.clients)
# ---------------------------------------------------------------------------

def _splitmix32_np(x):
    """Independent numpy transcription of the pinned counter hash."""
    x = np.uint32(x)
    with np.errstate(over="ignore"):
        x = np.uint32((np.uint32(x ^ (x >> np.uint32(16)))
                       * np.uint32(0x7FEB352D)))
        x = np.uint32((np.uint32(x ^ (x >> np.uint32(15)))
                       * np.uint32(0x846CA68B)))
    return np.uint32(x ^ (x >> np.uint32(16)))


def _mask_np(seed, rate, pods, devs, k, t):
    idx = np.arange(pods * devs * k, dtype=np.uint32)
    words = _splitmix32_np(
        idx ^ _splitmix32_np(np.uint32(seed) ^ _splitmix32_np(np.uint32(t))))
    return ((words >> np.uint32(8))
            < np.uint32(round(rate * (1 << 24)))).astype(np.float32
                                                         ).reshape(pods,
                                                                   devs, k)


def test_participation_mask_scheme_is_pinned():
    """The mask of round t is EXACTLY the splitmix32 counter hash of
    (seed, t, client index) -- the checkpoint contract, transcribed
    here independently in numpy: any change to the derivation breaks
    mid-round restores and must fail this test.  (The scheme is
    deliberately NOT jax.random: threefry is not partition-stable in
    this jax version, so a sharded train step would draw a different
    quorum than the eager oracle.)"""
    cfg = vclients.ClientConfig(count=3, participation="bernoulli",
                                rate=0.4, seed=9)
    for t in (0, 1, 7):
        ref = _mask_np(9, 0.4, 2, 2, 3, t)
        got = vclients.participation_mask(cfg, 2, 2, t)
        np.testing.assert_array_equal(np.asarray(got), ref)
        # pure function: recomputation is identical (restore mid-round)
        np.testing.assert_array_equal(
            np.asarray(vclients.participation_mask(cfg, 2, 2, t)),
            np.asarray(got))
        # ... and jit/sharding cannot perturb it (elementwise uint32
        # ops over an iota partition exactly)
        np.testing.assert_array_equal(
            np.asarray(jax.jit(vclients.participation_mask,
                               static_argnums=(0, 1, 2))(cfg, 2, 2,
                                                         jnp.asarray(t))),
            ref)
    fixed = vclients.ClientConfig(count=4, participation="fixed",
                                  rate=0.5, seed=9)
    for t in (0, 3):
        m = np.asarray(vclients.participation_mask(fixed, 2, 2, t))
        assert m.shape == (2, 2, 4)
        # exactly round(rate * D * K) participants per edge, every round
        np.testing.assert_array_equal(m.reshape(2, -1).sum(axis=1), 4)
        # the m smallest hash words of the edge vote
        words = np.asarray(vclients._client_words(fixed, 2, 2, t)
                           ).reshape(2, 8)
        for q in range(2):
            chosen = np.sort(np.argsort(words[q], kind="stable")[:4])
            np.testing.assert_array_equal(
                np.flatnonzero(m.reshape(2, 8)[q]), chosen)


def test_participation_mask_depends_only_on_round():
    """Inside the train step the mask key is step // T_E: every local
    step of a round (and a restart from a mid-round checkpoint) draws
    the identical quorum; different rounds resample."""
    cfg = vclients.ClientConfig(count=8, participation="bernoulli",
                                rate=0.5, seed=3)
    t_e = 5

    @jax.jit
    def mask_at(step):
        return vclients.participation_mask(cfg, 1, 2, step // t_e)

    r0 = np.asarray(mask_at(jnp.asarray(0)))
    for step in (1, 4):
        np.testing.assert_array_equal(np.asarray(mask_at(jnp.asarray(step))),
                                      r0)
    r1 = np.asarray(mask_at(jnp.asarray(t_e)))
    assert not np.array_equal(r0, r1)


def test_client_config_validation():
    import pytest
    with pytest.raises(ValueError, match="participation"):
        vclients.ClientConfig(participation="sometimes")
    with pytest.raises(ValueError, match="rate"):
        vclients.ClientConfig(participation="bernoulli", rate=0.0)
    with pytest.raises(ValueError, match="clients per device"):
        vclients.ClientConfig(count=0)
    with pytest.raises(ValueError, match="nonnegative"):
        vclients.ClientConfig(count=1, weights=(((-1,),),))
    with pytest.raises(ValueError, match="shape"):
        vclients.ClientConfig(count=2, weights=(((1,),),)).weight_array(1, 1)
    cfg = vclients.ClientConfig(count=2, weights=(((3, 4), (1, 2)),))
    assert cfg.active and cfg.weight_bound(1, 2) == 10
    assert not vclients.ClientConfig().active
