"""gemma3-12b [dense]: 48L d3840 16H (kv=8) ff15360 v262144; 5:1
local:global sliding-window attention (window 1024), tied embeddings,
qk-norm, 128k context. [hf:google/gemma-3-1b-pt; unverified]
"""
import dataclasses

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360,
    vocab=262144, head_dim=240,
    window=1024, local_global=(5, 1), qk_norm=True,
    rope_theta=1e4, rope_theta_global=1e6,
    tie_embed=True, embed_scale=True, act="gelu",
    param_mode="fsdp", supports_long_context=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma3-12b-smoke", n_layers=12, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, head_dim=16, window=8,
    param_mode="replicated",
)
