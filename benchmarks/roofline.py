"""Roofline analysis from the dry-run reports (deliverable g).

Hardware model (TPU v5e, per chip):
    peak bf16 compute   197 TFLOP/s
    HBM bandwidth       819 GB/s
    ICI link bandwidth  ~50 GB/s  (pod axis rides DCN in reality; we price
                                   it at ICI rate and note the caveat --
                                   its bytes are 1/T_E-amortized anyway)

The SPMD HLO module is per-device, so analyzer outputs are already
per-chip:
    compute_term    = flops / 197e12            [s]
    memory_term     = hbm_bytes / 819e9         [s]
    collective_term = collective_bytes / 50e9   [s]

For train cells the per-step cost amortizes the round structure:
    per_step = ((T_E - 1) * local_step + sync_step) / T_E

``roofline fraction`` = compute_term / max(all terms): 1.0 means the cell
is perfectly compute-bound at peak; the dominant term names the
bottleneck the perf loop attacks (EXPERIMENTS.md Sec. Perf).
"""
from __future__ import annotations

import json
import pathlib

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

REPORT_DIR = pathlib.Path(__file__).resolve().parents[1] / "reports" / \
    "dryrun"

MESH_CHIPS = {"16x16": 256, "2x16x16": 512}


def _terms(h: dict) -> dict:
    return {
        "compute_s": h["flops"] / PEAK_FLOPS,
        "memory_s": h["hbm_bytes"] / HBM_BW,
        "collective_s": h["collective_bytes_total"] / ICI_BW,
        "per_axis_bytes": h.get("per_axis_bytes", {}),
    }


def _combine_round(local: dict, sync: dict, t_e: int) -> dict:
    out = {}
    for k in ("compute_s", "memory_s", "collective_s"):
        out[k] = ((t_e - 1) * local[k] + sync[k]) / t_e
    out["per_axis_bytes"] = {
        a: ((t_e - 1) * local["per_axis_bytes"].get(a, 0.0)
            + sync["per_axis_bytes"].get(a, 0.0)) / t_e
        for a in set(local["per_axis_bytes"]) | set(
            sync["per_axis_bytes"])}
    return out


def analyze_cell(cell: dict, t_e: int = 15) -> dict | None:
    if cell.get("skipped"):
        return None
    phases = cell["phases"]
    if "local_step" in phases:
        local = _terms(phases["local_step"]["hlo"])
        sync = _terms(phases["sync_step"]["hlo"])
        terms = _combine_round(local, sync, t_e)
        kind = "train"
    else:
        ph = next(iter(phases.values()))
        terms = _terms(ph["hlo"])
        kind = next(iter(phases))
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    bound = max(terms["compute_s"], terms["memory_s"],
                terms["collective_s"])
    chips = MESH_CHIPS[cell["mesh"]]
    # MODEL_FLOPS = 6 * N(_active) * tokens, global; HLO flops are per-chip
    n_params = cell.get("params") or 0
    out = {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "kind": kind,
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "per_axis_bytes": terms["per_axis_bytes"],
        "dominant": dominant.replace("_s", ""),
        "roofline_fraction": (terms["compute_s"] / bound) if bound else 0.0,
        "step_time_bound_s": bound,
        "chips": chips,
        "params": n_params,
    }
    return out


def model_flops(arch_cfg, shape, n_params_active: int) -> float:
    """6 * N_active * D (training tokens) -- global, per step."""
    if shape.kind != "train":
        return 2.0 * n_params_active * shape.global_batch * (
            shape.seq_len if shape.kind == "prefill" else 1)
    return 6.0 * n_params_active * shape.global_batch * shape.seq_len


_PARAM_CACHE: dict = {}


def exact_params(arch_name: str) -> int:
    """Exact parameter count from abstract init shapes (the stored
    'params' field of early reports hit an int32 overflow)."""
    if arch_name not in _PARAM_CACHE:
        import math
        import jax
        from repro import configs
        from repro.models import build as mbuild
        cfg = configs.get_config(arch_name)
        arch = mbuild.make_archdef(cfg, 16)
        shapes = jax.eval_shape(lambda r: mbuild.init_params(arch, r),
                                jax.random.PRNGKey(0))
        _PARAM_CACHE[arch_name] = sum(
            math.prod(a.shape) for a in jax.tree.leaves(shapes))
    return _PARAM_CACHE[arch_name]


def load_cells(tag: str = "baseline", report_dir: pathlib.Path | None = None):
    rd = report_dir or REPORT_DIR
    cells = []
    for f in sorted(rd.glob(f"{tag}.*.json")):
        cell = json.loads(f.read_text())
        if not cell.get("skipped"):
            cell["params"] = exact_params(cell["arch"])
        cells.append(cell)
    return cells


def roofline_rows(tag: str = "baseline", t_e: int = 15):
    """CSV rows for benchmarks.run + the EXPERIMENTS.md table."""
    from repro import configs
    from repro.models.config import SHAPES
    rows = []
    for cell in load_cells(tag):
        r = analyze_cell(cell, t_e)
        if r is None:
            rows.append((f"roofline/{cell['arch']}/{cell['shape']}/"
                         f"{cell['mesh']}", 0.0,
                         f"SKIPPED: {cell['skip_reason'][:60]}"))
            continue
        cfg = configs.get_config(cell["arch"])
        shape = SHAPES[cell["shape"]]
        mf = model_flops(cfg, shape, cfg.active_param_count())
        hlo_global = r["compute_s"] * PEAK_FLOPS * r["chips"]
        useful = mf / hlo_global if hlo_global else 0.0
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            r["step_time_bound_s"] * 1e6,
            f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
            f"collective={r['collective_s']:.4f}s dom={r['dominant']} "
            f"roofline_frac={r['roofline_fraction']:.3f} "
            f"useful_flops_ratio={useful:.3f}"))
    return rows
