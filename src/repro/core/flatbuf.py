"""Flat-buffer gradient bucketization: one contiguous view of a pytree.

The sign->pack->vote->update sweep is elementwise and coordinate-order
agnostic, so running it per-leaf under ``jax.tree.map`` only buys N small
dispatches, N ragged pads, and N tiny collectives.  This module precomputes
a **static leaf layout** for any float pytree so the hot path can operate on
ONE contiguous ``[..., n_pad]`` buffer (or its 1-bit packed twin) instead:

  * every leaf is assigned a coordinate range ``[offset, offset + size)``
    with ``offset % 32 == 0`` (leaf tails padded to the 32-bit pack word),
    so the float and packed-word domains share the same layout:
    leaf i's words are exactly ``[offset/32, (offset + padded)/32)``;
  * the total is padded to the 32*128 TPU tile (one packed word per lane),
    so 2D views handed to the Pallas kernels need no further padding;
  * dtype promotion rule: the buffer dtype is ``jnp.promote_types`` over
    all leaf dtypes (float leaves only) -- promotion is widening, so
    ``unflatten_tree(flatten_tree(t))`` restores every leaf bit-exactly.

``flatten_tree``/``unflatten_tree`` are cheap reshape/slice views around a
single concatenate (unflatten is pure views); ``pack_tree`` fuses the DC
correction ``u + rho*delta`` and the sign into the per-leaf pack and
concatenates at the *word* level, so the full-precision buffer is never
materialized on the fallback path (the wire payload is 1/32 the tally).

Padding convention: float padding is 0 and ``sgn(0) = +1``, bit-identical
to ``signs.pack_signs``'s all-ones tail bits -- so
``pack_tree(layout, t) == pack_signs(sgn(flatten_tree(layout, t)))``
holds bitwise (tested in tests/test_flatbuf.py).

State layouts
-------------
PR 1 used the flat buffer only as a *transient* inside the fused
transport; with ``AlgoConfig(state_layout="flat")`` (``core.hier``) the
buffer becomes the *persistent* master state.  :class:`FlatState` wraps
one ``[*batch, n_pad]`` buffer together with its static
:class:`FlatLayout` as a single pytree node (the layout rides in the
treedef aux data, so jit/eval_shape/checkpoint traversals see exactly
one array leaf).  Under ``state_layout="flat"``:

  * ``TrainState.params`` / ``delta`` / ``delta_next`` are
    ``FlatState([P, n_pad])`` buffers (master / delta dtype), and the
    replicated-regime EF / momentum buffers are ``FlatState([P, D,
    n_pad])`` -- the whole-model update and the pre-sign correction
    ``u + rho*delta`` are single elementwise sweeps instead of per-leaf
    tree maps;
  * leaf views are materialized only at the loss-function boundary and
    at checkpoint/eval edges via :meth:`FlatState.tree`
    (``unflatten_tree`` is pure slice/reshape views);
  * coordinates beyond each leaf's ``size`` (tail + tile padding, and
    in sharded layouts the ``shard_pad`` zero tail of an uneven leaf's
    last block) are *don't-care*: the fused vote/update kernel sweeps
    them along with the real coordinates (their gradient is 0 -> vote
    +1, so they drift), but no view ever reads them and
    ``checkpoint.store`` round-trips only the real coordinates.

The layout of a given tree is deterministic (flatten order x the rules
above), so two runs -- or a tree-state checkpoint and a flat-state run
-- always agree on where every leaf lives.

Model-axis sharded layouts (per-shard buckets)
----------------------------------------------
``make_layout(..., sharding=ModelSharding(...))`` lays the tree out as
``shards`` identical **buckets**, one per model (TP) shard, so the flat
buffer can live sharded along the mesh's model axis end to end -- no
leaf is ever gathered to build or read the buffer:

  * a leaf whose PartitionSpec names the model axis on a nonzero dim
    contributes its *local block* to each bucket (bucket m holds block m
    of the leaf along ``LeafSlot.shard_dim``).  Extents that do NOT
    divide by ``shards`` are padded *inside the layout*: the dim is
    zero-extended up to ``shards * ceil(extent / shards)``
    (``LeafSlot.shard_pad`` records the tail), so every bucket still
    holds one equal block and the leaf stays sharded end to end -- the
    zero tail is don't-care exactly like tile padding (``sgn(0) = +1``,
    never read back, never checkpointed);
  * every other leaf (replicated specs, zero-size dims) is **copied
    whole into every bucket** -- each shard votes/updates its own copy
    from identical inputs, so the copies stay bit-identical by
    construction and any one of them is the leaf;
  * slots store *local* (per-bucket) geometry; the buckets share one
    slot table, each bucket is independently 32*128-tile aligned, and
    ``n_pad = shards * bucket_pad`` with bucket m owning the contiguous
    word range ``[m * bucket_pad/32, (m+1) * bucket_pad/32)``.

``layout.bucket()`` is the shards=1 layout of ONE bucket: inside a
``shard_map`` program (see ``core.shardflat``) every rank runs the
ordinary ``flatten_tree``/``unflatten_tree``/``pack_tree`` on its local
block with the bucket layout, which is how the sharded layout stays a
pure re-indexing of the same per-coordinate arithmetic.  The global
(reference) ``flatten_tree``/``unflatten_tree``/``pack_tree`` here
implement identical semantics with static slices/concats and work on
any runtime -- they are the oracle the shard_map path is tested
against.  Coordinate ORDER differs from the unsharded layout (buckets
interleave leaf blocks), but the sign->vote->update sweep is
coordinate-order agnostic, so trajectories stay bit-identical
leaf-for-leaf.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import signs

PyTree = Any

PACK = signs.PACK_WIDTH          # 32 sign bits per uint32 word
LANES = 128                      # TPU lane count
TILE = PACK * LANES              # 4096 coords = 128 packed words


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Static placement of one leaf inside the flat buffer.

    For sharded layouts (``FlatLayout.shards > 1``) the geometry is
    LOCAL: ``shape``/``size``/``padded`` describe the per-bucket block
    and ``offset`` is the offset *within* a bucket.  ``shard_dim`` is
    the leaf dim the model axis shards, or None for a leaf copied whole
    into every bucket.  ``shard_pad`` is the number of zero-filled rows
    the layout appends to the GLOBAL extent along ``shard_dim`` so it
    divides evenly (uneven TP leaves): logical global extent =
    ``shape[shard_dim] * shards - shard_pad``.
    """
    shape: tuple[int, ...]       # leaf dims (batch dims excluded)
    dtype: Any                   # original leaf dtype (restored on unflatten)
    size: int                    # prod(shape)
    padded: int                  # size padded to a PACK multiple
    offset: int                  # coordinate offset; offset % PACK == 0
    shard_dim: int | None = None  # model-sharded leaf dim (sharded layouts)
    shard_pad: int = 0           # zero tail padding the global shard_dim
                                 # extent up to a multiple of shards

    @property
    def word_offset(self) -> int:
        return self.offset // PACK

    @property
    def words(self) -> int:
        return self.padded // PACK

    def global_shape(self, shards: int) -> tuple[int, ...]:
        """The LOGICAL (unpadded) leaf shape this slot stores."""
        if self.shard_dim is None:
            return self.shape
        d = self.shard_dim
        return (self.shape[:d] + (self.shape[d] * shards - self.shard_pad,)
                + self.shape[d + 1:])

    def global_size(self, shards: int) -> int:
        """Number of REAL (logical) coordinates this slot stores."""
        return int(functools.reduce(
            lambda a, b: a * b, self.global_shape(shards), 1))


@dataclasses.dataclass(frozen=True)
class ModelSharding:
    """How the model (TP) axis divides a tree into per-shard buckets.

    ``specs`` is a pytree of ``jax.sharding.PartitionSpec`` over the
    LEAF dims (batch dims excluded) -- the same trees ``ModelBundle``
    carries as master/compute specs.  A leaf shards on the first dim
    whose spec entry names ``axis`` and has a nonzero extent (uneven
    extents are zero-padded up to a multiple of ``shards`` inside the
    layout, see ``LeafSlot.shard_pad``); everything else is copied
    whole into every bucket.
    """
    shards: int
    axis: str
    specs: Any


def _path_key(path) -> str:
    """'/'-joined leaf path key (same convention as checkpoint.store)."""
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


@functools.lru_cache(maxsize=None)
def _warn_zero_copy(leaf_key: str, shape: tuple[int, ...], dim: int,
                    shards: int):
    # keyed on the leaf PATH, not just the shape: two different leaves
    # of equal shape must each warn, while re-laying the same tree out
    # (master / delta / EF layouts share geometry) stays deduped.  This
    # is the ONE remaining per-bucket-copy fallback for a spec'd model
    # dim -- a zero-size extent carries no data, so nothing is lost,
    # but the spec is almost certainly a mistake worth surfacing.
    warnings.warn(
        f"flatbuf sharded layout: leaf {leaf_key!r} (shape {shape}) is "
        f"model-sharded on zero-size dim {dim}; it carries no data, so "
        f"it is stored as a per-bucket COPY rather than {shards} padded "
        f"blocks.", stacklevel=3)


def _spec_shard_dim(spec, axis: str, shape: tuple[int, ...],
                    shards: int, leaf_key: str = "") -> int | None:
    if spec is None:
        return None
    for i, entry in enumerate(spec):
        names = entry if isinstance(entry, tuple) else (entry,)
        if axis in names:
            if i < len(shape) and shape[i] > 0:
                return i         # uneven extents shard too: padded blocks
            if i < len(shape):
                _warn_zero_copy(leaf_key, shape, i, shards)
            return None          # zero-size dim -> per-bucket copy
    return None


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Static layout of a pytree as one tile-aligned flat buffer."""
    treedef: Any
    slots: tuple[LeafSlot, ...]
    n: int                       # distinct real coordinates
    n_pad: int                   # buffer length; n_pad % (shards*TILE) == 0
    dtype: Any                   # promoted float dtype of the flat buffer
    shards: int = 1              # model-axis buckets (1 = unsharded)

    @property
    def n_words(self) -> int:
        return self.n_pad // PACK

    @property
    def bucket_pad(self) -> int:
        """Coordinates per model-shard bucket (== n_pad when shards=1)."""
        return self.n_pad // self.shards

    @property
    def bucket_words(self) -> int:
        return self.bucket_pad // PACK

    def bucket(self) -> "FlatLayout":
        """The shards=1 layout of ONE bucket (identity when unsharded).

        This is what a shard_map program uses on its local block: the
        slots already store local geometry, so the bucket layout is the
        same slot table over a ``bucket_pad``-long buffer.
        """
        if self.shards == 1:
            return self
        return dataclasses.replace(
            self, shards=1, n_pad=self.bucket_pad,
            n=sum(s.size for s in self.slots))


@jax.tree_util.register_pytree_node_class
class FlatState:
    """One flat buffer + its static :class:`FlatLayout`, as a pytree node.

    The buffer is the single array leaf; ``(layout, batch_dims)`` ride in
    the treedef aux data, so the layout is available statically wherever
    the state travels (train step, eval_shape, checkpoint store) and two
    ``FlatState``s with the same layout are structure-compatible under
    ``jax.tree`` transforms, ``lax.cond`` and donation.
    """

    __slots__ = ("buf", "layout", "batch_dims")

    def __init__(self, buf, layout: FlatLayout, batch_dims: int = 1):
        self.buf = buf
        self.layout = layout
        self.batch_dims = batch_dims

    def tree(self, cast: bool = True) -> PyTree:
        """Materialize the leaf views (slice/reshape, no copy)."""
        return unflatten_tree(self.layout, self.buf,
                              batch_dims=self.batch_dims, cast=cast)

    def replace(self, buf) -> "FlatState":
        return FlatState(buf, self.layout, self.batch_dims)

    def tree_flatten(self):
        return (self.buf,), (self.layout, self.batch_dims)

    @classmethod
    def tree_unflatten(cls, aux, children):
        layout, batch_dims = aux
        return cls(children[0], layout, batch_dims)

    def __repr__(self):
        return (f"FlatState(buf={getattr(self.buf, 'shape', self.buf)!r}, "
                f"n={self.layout.n}, n_pad={self.layout.n_pad}, "
                f"batch_dims={self.batch_dims})")


def from_tree(tree: PyTree, batch_dims: int = 0, dtype: Any = None,
              sharding: ModelSharding | None = None) -> FlatState:
    """Lay out and flatten ``tree`` into a :class:`FlatState` in one call."""
    layout = make_layout(tree, batch_dims=batch_dims, sharding=sharding)
    buf = flatten_tree(layout, tree, batch_dims=batch_dims, dtype=dtype)
    return FlatState(buf, layout, batch_dims)


def with_dtype(layout: FlatLayout, dtype: Any) -> FlatLayout:
    """The same coordinate layout, re-labeled for a buffer of ``dtype``.

    Auxiliary flat-state buffers (DC delta, EF residual, momentum) share
    the master's slot geometry but store a different dtype; their slots
    must say so, or ``FlatState.tree()`` / checkpoint metadata would
    report the master dtype for them.
    """
    dtype = jnp.dtype(dtype)
    slots = tuple(dataclasses.replace(s, dtype=dtype) for s in layout.slots)
    return dataclasses.replace(layout, slots=slots, dtype=dtype)


def make_layout(tree: PyTree, batch_dims: int = 0, tile: int = TILE,
                sharding: ModelSharding | None = None) -> FlatLayout:
    """Compute the static layout of ``tree`` (shapes/dtypes only).

    batch_dims: number of leading dims shared by every leaf (e.g. 2 for
    ``[P, D, *leaf]`` per-device gradients) that stay un-flattened.

    sharding: lay the tree out as per-model-shard buckets (see the
    module docstring).  Uneven extents shard as padded blocks, so a
    sharding normalizes back to the unsharded (shards=1) layout only
    when NO leaf spec names the model axis on a nonzero dim -- callers
    can pass the mesh sharding unconditionally.
    """
    keyed, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = [leaf for _, leaf in keyed]
    leaf_keys = [_path_key(p) for p, _ in keyed]
    if not leaves:
        raise ValueError("cannot lay out an empty pytree")
    shards = sharding.shards if sharding is not None else 1
    if shards > 1:
        spec_leaves = treedef.flatten_up_to(sharding.specs)
    else:
        spec_leaves = [None] * len(leaves)
    kinds = set()
    for leaf in leaves:
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            kinds.add("float")
        elif jnp.issubdtype(leaf.dtype, jnp.signedinteger):
            kinds.add("int")
        else:
            raise ValueError(
                "flatbuf only buckets float / signed-int leaves, got "
                f"{leaf.dtype}")
    if len(kinds) > 1:
        # jnp.promote_types(int32, bfloat16) == bfloat16 -- NOT widening,
        # so a mixed buffer could corrupt int values; keep trees
        # dtype-kind homogeneous (sign trees are all-int, grads all-float)
        raise ValueError("flatbuf trees must not mix int and float leaves")
    slots = []
    offset = 0
    dtype = None
    for leaf, spec, key in zip(leaves, spec_leaves, leaf_keys):
        shape = tuple(leaf.shape[batch_dims:])
        sd = (_spec_shard_dim(spec, sharding.axis, shape, shards, key)
              if shards > 1 else None)
        sp = 0
        if sd is not None:
            # pad the sharded extent up to the next multiple of shards
            # so every bucket holds one equal local block (zero tail =
            # don't-care coordinates, same convention as tile padding)
            blk = -(-shape[sd] // shards)
            sp = blk * shards - shape[sd]
            shape = shape[:sd] + (blk,) + shape[sd + 1:]
        size = int(functools.reduce(lambda a, b: a * b, shape, 1))
        padded = _ceil_to(max(size, 1), PACK)
        slots.append(LeafSlot(shape=shape, dtype=leaf.dtype, size=size,
                              padded=padded, offset=offset, shard_dim=sd,
                              shard_pad=sp))
        offset += padded
        dtype = (leaf.dtype if dtype is None
                 else jnp.promote_types(dtype, leaf.dtype))
    if shards > 1 and all(s.shard_dim is None for s in slots):
        shards = 1               # nothing shards: don't pay M-way copies
    n = sum(s.global_size(shards) if s.shard_dim is not None else s.size
            for s in slots)
    return FlatLayout(treedef=treedef, slots=tuple(slots), n=n,
                      n_pad=shards * _ceil_to(offset, tile),
                      dtype=jnp.dtype(dtype), shards=shards)


def _pad_shard_tail(slot: LeafSlot, leaf: jax.Array, batch_dims: int):
    """Zero-extend an uneven sharded leaf's shard_dim to blk * shards.

    Zero fill keeps the tail don't-care under the padding convention
    (``sgn(0) = +1``); no view ever reads it back.
    """
    if slot.shard_dim is None or not slot.shard_pad:
        return leaf
    pads = [(0, 0)] * leaf.ndim
    pads[batch_dims + slot.shard_dim] = (0, slot.shard_pad)
    return jnp.pad(leaf, pads)


def pad_tree(layout: FlatLayout, tree: PyTree,
             batch_dims: int = 0) -> PyTree:
    """Logical tree -> the layout's padded-shard shapes (zero tails).

    Every uneven sharded leaf gains ``shard_pad`` zero rows along its
    ``shard_dim`` so each leaf dim divides evenly by ``layout.shards``
    -- the shapes a ``shard_map`` program (``core.shardflat``) needs at
    its boundary.  Identity for even/copy slots and unsharded layouts.
    """
    leaves = layout.treedef.flatten_up_to(tree)
    return layout.treedef.unflatten(
        [_pad_shard_tail(s, leaf, batch_dims)
         for s, leaf in zip(layout.slots, leaves)])


def unpad_tree(layout: FlatLayout, tree: PyTree,
               batch_dims: int = 0) -> PyTree:
    """Inverse of :func:`pad_tree`: slice each leaf back to its logical
    extent (drops the don't-care zero tail; pure static slices)."""
    leaves = layout.treedef.flatten_up_to(tree)
    out = []
    for slot, leaf in zip(layout.slots, leaves):
        if slot.shard_dim is not None and slot.shard_pad:
            ax = batch_dims + slot.shard_dim
            leaf = jax.lax.slice_in_dim(
                leaf, 0, leaf.shape[ax] - slot.shard_pad, axis=ax)
        out.append(leaf)
    return layout.treedef.unflatten(out)


def bucket_trees(layout: FlatLayout, tree: PyTree,
                 batch_dims: int = 0) -> list[PyTree]:
    """Per-bucket local trees of a sharded layout (static slices).

    Bucket m's tree holds block m of every sharded leaf (along its
    ``shard_dim``, zero-padded tail for uneven extents) and the full
    leaf for per-bucket copies -- exactly what rank m of a shard_map
    program sees locally.
    """
    leaves = [_pad_shard_tail(s, leaf, batch_dims)
              for s, leaf in zip(layout.slots,
                                 layout.treedef.flatten_up_to(tree))]
    out = []
    for m in range(layout.shards):
        parts = []
        for slot, leaf in zip(layout.slots, leaves):
            if slot.shard_dim is None:
                parts.append(leaf)
            else:
                ax = batch_dims + slot.shard_dim
                w = slot.shape[slot.shard_dim]
                parts.append(jax.lax.slice_in_dim(leaf, m * w, (m + 1) * w,
                                                  axis=ax))
        out.append(layout.treedef.unflatten(parts))
    return out


def _flat_leaf(slot: LeafSlot, leaf: jax.Array, batch_dims: int):
    batch = leaf.shape[:batch_dims]
    flat = leaf.reshape(batch + (slot.size,))
    if slot.padded != slot.size:
        flat = jnp.pad(flat, [(0, 0)] * batch_dims
                       + [(0, slot.padded - slot.size)])
    return flat


def flatten_tree(layout: FlatLayout, tree: PyTree, batch_dims: int = 0,
                 dtype: Any = None) -> jax.Array:
    """tree -> ``[*batch, n_pad]`` buffer in the (promoted) buffer dtype.

    Sharded layouts build each bucket from the leaf blocks it owns
    (static slices -- the reference semantics of the shard_map path in
    ``core.shardflat``, which never moves a block off its shard).
    """
    if layout.shards > 1:
        bucket = layout.bucket()
        return jnp.concatenate(
            [flatten_tree(bucket, t, batch_dims=batch_dims, dtype=dtype)
             for t in bucket_trees(layout, tree, batch_dims)], axis=-1)
    dtype = layout.dtype if dtype is None else dtype
    leaves = layout.treedef.flatten_up_to(tree)
    parts = [_flat_leaf(s, leaf.astype(dtype), batch_dims)
             for s, leaf in zip(layout.slots, leaves)]
    buf = jnp.concatenate(parts, axis=-1)
    tail = layout.n_pad - buf.shape[-1]
    if tail:
        buf = jnp.pad(buf, [(0, 0)] * batch_dims + [(0, tail)])
    return buf


def unflatten_tree(layout: FlatLayout, buf: jax.Array, batch_dims: int = 0,
                   cast: bool = True) -> PyTree:
    """``[*batch, n_pad]`` buffer -> pytree of slice views.

    cast=True restores each leaf's original dtype (exact for widening
    promotions); cast=False keeps ``buf.dtype`` (e.g. int8 vote bits).

    Sharded layouts reassemble each sharded leaf by concatenating its
    per-bucket blocks along ``shard_dim`` (then dropping the uneven
    ``shard_pad`` zero tail); per-bucket copies read bucket 0 (all
    copies are bit-identical by construction).
    """
    if layout.shards > 1:
        bucket = layout.bucket()
        bp = layout.bucket_pad
        parts = [
            bucket.treedef.flatten_up_to(
                unflatten_tree(bucket, buf[..., m * bp:(m + 1) * bp],
                               batch_dims=batch_dims, cast=cast))
            for m in range(layout.shards)]
        leaves = []
        for i, slot in enumerate(layout.slots):
            if slot.shard_dim is None:
                leaves.append(parts[0][i])
            else:
                ax = batch_dims + slot.shard_dim
                full = jnp.concatenate([p[i] for p in parts], axis=ax)
                if slot.shard_pad:
                    full = jax.lax.slice_in_dim(
                        full, 0, full.shape[ax] - slot.shard_pad, axis=ax)
                leaves.append(full)
        return layout.treedef.unflatten(leaves)
    batch = buf.shape[:batch_dims]
    leaves = []
    for s in layout.slots:
        leaf = buf[..., s.offset:s.offset + s.size].reshape(batch + s.shape)
        leaves.append(leaf.astype(s.dtype) if cast else leaf)
    return layout.treedef.unflatten(leaves)


def _with_mid_axes(x: jax.Array, batch_dims: int, target_batch: int):
    """[*b, n] -> [*b, 1...1, n] broadcastable against target_batch dims."""
    for _ in range(target_batch - batch_dims):
        x = x[..., None, :]
    return x


def pack_tree(layout: FlatLayout, tree: PyTree, batch_dims: int = 0,
              delta: PyTree | None = None, rho: float = 0.0,
              delta_batch_dims: int = 0) -> jax.Array:
    """Fused (u + rho*delta) -> sign -> 1-bit pack, concatenated per word.

    Returns ``[*batch, n_pad/32]`` uint32.  The correction is added in each
    leaf's own dtype -- exactly ``u + rho * delta.astype(u.dtype)``, the
    same arithmetic the per-leaf tree path uses -- so votes stay
    bit-identical to the ``ag_packed`` transport.  Word concatenation means
    the full-precision flat buffer never exists: only the 1-bit payload is
    contiguous.  Tail words are all-ones (+1 signs), matching
    ``pack_signs`` padding.
    """
    if layout.shards > 1:
        bucket = layout.bucket()
        uts = bucket_trees(layout, tree, batch_dims)
        dts = (bucket_trees(layout, delta, delta_batch_dims)
               if delta is not None else [None] * layout.shards)
        return jnp.concatenate(
            [pack_tree(bucket, ut, batch_dims=batch_dims, delta=dt,
                       rho=rho, delta_batch_dims=delta_batch_dims)
             for ut, dt in zip(uts, dts)], axis=-1)
    leaves = layout.treedef.flatten_up_to(tree)
    dl_leaves = (layout.treedef.flatten_up_to(delta)
                 if delta is not None else [None] * len(leaves))
    parts = []
    for slot, leaf, dl in zip(layout.slots, leaves, dl_leaves):
        u = leaf.reshape(leaf.shape[:batch_dims] + (slot.size,))
        if slot.size == 0:
            # pack_signs pads to ceil(size/32) words == 0 for empty
            # leaves, but the slot still occupies `words` all-padding
            # words (+1 signs) so later offsets stay aligned.
            parts.append(jnp.full(leaf.shape[:batch_dims] + (slot.words,),
                                  0xFFFFFFFF, jnp.uint32))
            continue
        if dl is not None and rho:
            dlf = dl.reshape(dl.shape[:delta_batch_dims] + (slot.size,))
            dlf = _with_mid_axes(dlf, delta_batch_dims, batch_dims)
            u = u + rho * dlf.astype(u.dtype)
        parts.append(signs.pack_signs(signs.sgn(u)))      # pads to +1 bits
    words = jnp.concatenate(parts, axis=-1)
    tail = layout.n_words - words.shape[-1]
    if tail:
        words = jnp.pad(words, [(0, 0)] * batch_dims + [(0, tail)],
                        constant_values=jnp.uint32(0xFFFFFFFF))
    return words
