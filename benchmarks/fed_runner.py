"""Shared runner for the paper-reproduction benchmarks (Figs. 2-4).

Reproduces the paper's setup on the offline synthetic EMNIST-like task:
Q=4 edges x 5 devices, Dirichlet(alpha=0.1) inter-edge skew, B=400 (paper)
scaled to B=64 at 30% of the samples for CPU wall-time, T_E=15, mu=5e-3
(sign) / 0.5 (SGD, tuned for the synthetic task), rho=0.2.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core import ref_fed, signs
from repro.data import emnist_like
from repro.models import mlp


@dataclasses.dataclass
class FedBenchCfg:
    method: str = "dc_hier_signsgd"
    rho: float = 0.2
    iid: bool = False
    rounds: int = 8
    t_e: int = 15
    batch: int = 64
    mu: float = 5e-3
    mu_sgd: float = 0.5
    seed: int = 0
    q_edges: int = 4
    devices_per_edge: int = 5
    n_train: int = 6000
    decay: bool = False


def run_fed(cfg: FedBenchCfg):
    """Returns dict with accuracy/loss curves + wall time + uplink bits."""
    dcfg = emnist_like.FedDataCfg(
        n_train=cfg.n_train, n_test=1500, alpha=0.1, iid=cfg.iid,
        seed=cfg.seed, q_edges=cfg.q_edges,
        devices_per_edge=cfg.devices_per_edge)
    dev, test, ew, dw = emnist_like.make_federated_data(dcfg)
    rng = np.random.default_rng(cfg.seed)
    params = mlp.init_mlp(jax.random.PRNGKey(cfg.seed))
    state = ref_fed.init_state(params, cfg.q_edges)
    hcfg = ref_fed.HierConfig(mu=cfg.mu, mu_sgd=cfg.mu_sgd, t_e=cfg.t_e,
                              rho=cfg.rho, method=cfg.method,
                              decay=cfg.decay)
    accs, losses = [], []
    t0 = time.time()
    for t in range(cfg.rounds):
        batches = [[[emnist_like.device_batches(dev, q, k, cfg.batch, rng)
                     for _ in range(cfg.t_e)]
                    for k in range(cfg.devices_per_edge)]
                   for q in range(cfg.q_edges)]
        anchors = [[emnist_like.device_batches(dev, q, k, 4 * cfg.batch,
                                               rng)
                    for k in range(cfg.devices_per_edge)]
                   for q in range(cfg.q_edges)]
        state = ref_fed.global_round(state, hcfg, mlp.grad_fn, batches,
                                     anchors, ew, dw,
                                     jax.random.PRNGKey(1000 + t))
        accs.append(float(mlp.accuracy(state.w, test)))
        losses.append(float(mlp.loss_fn(
            state.w, {"x": test["x"][:512], "y": test["y"][:512]})))
    wall = time.time() - t0
    d = mlp.param_count(params)
    return {
        "acc": accs, "loss": losses,
        "wall_s_per_round": wall / cfg.rounds,
        "uplink_bits_per_round": signs.uplink_bits(cfg.method, d, cfg.t_e),
        "d": d,
    }
