"""Data pipeline: determinism, cursor-addressability, heterogeneity."""
import numpy as np
import pytest

from repro.data import emnist_like, synthetic


def _stream_cfg(**kw):
    base = dict(vocab=64, seq_len=16, batch_per_device=2, pods=2,
                devices_per_pod=2, seed=7)
    base.update(kw)
    return synthetic.LMStreamCfg(**base)


def test_stream_deterministic_and_cursor_addressable():
    s1 = synthetic.make_stream(_stream_cfg())
    s2 = synthetic.make_stream(_stream_cfg())
    np.testing.assert_array_equal(np.asarray(s1(5)["tokens"]),
                                  np.asarray(s2(5)["tokens"]))
    # different steps differ
    assert not np.array_equal(np.asarray(s1(5)["tokens"]),
                              np.asarray(s1(6)["tokens"]))


def test_stream_edge_heterogeneity_knob():
    """hetero=1: edges have different unigram dists; hetero=0: identical."""
    def edge_hist(hetero):
        s = synthetic.make_stream(_stream_cfg(hetero=hetero,
                                              batch_per_device=64))
        t = np.asarray(s(0)["tokens"])
        h = [np.bincount(t[q].ravel(), minlength=64) / t[q].size
             for q in range(2)]
        return np.abs(h[0] - h[1]).sum()   # L1 distance between edges

    assert edge_hist(1.0) > 3 * edge_hist(0.0)


def test_fed_data_dirichlet_skew():
    cfg = emnist_like.FedDataCfg(n_train=4000, n_test=500, alpha=0.1,
                                 seed=1)
    dev, test, ew, dw = emnist_like.make_federated_data(cfg)
    assert len(dev) == cfg.q_edges
    assert np.isclose(sum(ew), 1.0)
    for q in range(cfg.q_edges):
        assert np.isclose(sum(dw[q]), 1.0)
    # non-IID: edges should have very different class distributions
    hists = []
    for q in range(cfg.q_edges):
        ys = np.concatenate([d["y"] for d in dev[q]]) if any(
            len(d["y"]) for d in dev[q]) else np.zeros(1, int)
        hists.append(np.bincount(ys, minlength=10) / max(len(ys), 1))
    dists = [np.abs(hists[a] - hists[b]).sum()
             for a in range(4) for b in range(a)]
    assert max(dists) > 0.5


def test_fed_data_iid_mode_balanced():
    cfg = emnist_like.FedDataCfg(n_train=4000, n_test=500, iid=True, seed=1)
    dev, _, ew, _ = emnist_like.make_federated_data(cfg)
    assert max(ew) - min(ew) < 0.05


def test_device_batches_shapes():
    cfg = emnist_like.FedDataCfg(n_train=2000, n_test=100, seed=0)
    dev, _, _, _ = emnist_like.make_federated_data(cfg)
    rng = np.random.default_rng(0)
    b = emnist_like.device_batches(dev, 0, 0, 32, rng)
    assert b["x"].shape[0] == b["y"].shape[0] <= 32
    assert b["x"].shape[1] == cfg.dim
