"""Distributed HierSignSGD / DC-HierSignSGD train steps (the paper's core).

Step semantics (bit-equivalent to Algorithms 1/2, validated against
``repro.core.ref_fed``): each ``train_step`` call is one local step tau.
At a round boundary (step % T_E == 0) a prologue first runs

  1. cloud aggregation  v_q <- sum_q (D_q/N) v_q   (pod-axis all-reduce) --
     this is Alg. 1/2's end-of-round step folded into the next step's
     prologue (identical trajectory, single uniform step function), and
  2. (DC only) the anchor pass: c_q = sum_k (|D_qk|/D_q) grad f_qk(w),
     c = sum_q (D_q/N) c_q, delta_q = c - c_q.  With
     ``anchor_staleness=1`` (paper's pipelined variant) the freshly
     computed delta is *staged* and the previous round's delta is used, so
     devices at round t correct with c^(t-1) - c_q^(t-1) exactly as in
     Alg. 2; ``anchor_staleness=0`` is the fresh variant (extra cross-pod
     sync before local steps, no staging buffer).

The WHEN of step 1 is the cloud sync schedule (``core.schedule``,
selected by ``AlgoConfig.cloud_overlap``): ``"sync"`` issues and
commits the aggregate at the same boundary (the paper's barrier,
above); ``"overlap"`` commits the aggregate issued at the PREVIOUS
boundary and stages the fresh one in ``TrainState.agg_next`` -- edges
keep local-stepping on their local models while the cross-pod mean is
in flight, and the DC/SCAFFOLD/MTGC anchors refresh at the committed
(one-round-stale) aggregate.  Commit weights are pinned to issue-time
membership, so churn mid-flight is well-defined.

Then the local step: per-device grads -> (+ rho*delta, + EF residual) ->
sign -> majority vote over the ``data`` axis -> v_q <- v_q - mu * vote.
With an *active* ``AlgoConfig.clients`` (``core.clients``) the voter
axis is the merged virtual-client axis [P, D*K, ...]: batches are
carved per client, a per-round sampled participation mask and integer
data shares |D_qk| turn the vote into a weighted popcount (empty quorum
abstains), and the anchor/mean aggregations reweight to the
participating shares.  The inactive default is bitwise the legacy step.
``ClientConfig.mode="stream"`` runs the same round as a ``fori_loop``
over clients inside the step (``local_step_stream``): each client's
weighted sign plane folds into a persistent integer tally
(``votes.tally_*``) and the majority threshold is deferred until after
the loop -- O(model/32 + tally) live sign-plane memory instead of
O(K*model), bitwise identical to the merged axis on every cell.
With ``transport="fused"`` the sign/vote chain runs over ONE contiguous
flat buffer (``core.flatbuf`` layout, DC correction fused pre-sign,
Pallas kernels on TPU) instead of per-leaf tree maps -- bit-identical
votes, one gather (see the transport matrix in ``core.votes``).

Methods: hier_signsgd | dc_hier_signsgd | scaffold_hier_signsgd |
mtgc_hier_signsgd | hier_sgd | hier_local_qsgd, plus beyond-paper
options (error feedback, sign-momentum) in the replicated regime.
The scaffold/mtgc methods put alternative drift corrections in the same
pre-sign slot as DC: SCAFFOLD per-client control variates
(sgn(g + rho*(c_global - c_local_qk))) and MTGC's multi-timescale terms
(sgn(g + rho*(gamma_qk + eta_q)), edge term every round / cloud term
every ``cloud_period`` rounds) -- state in the corr_cl/corr_edge slots,
refreshed fresh at each round boundary (``compute_corrections``),
replicated regime only.

Regimes:
  * replicated: per-device grads are explicit ([P, D, ...] arrays) --
    supports every method + EF + momentum.
  * fsdp: the vote happens inside backprop via ``fsdp_lift`` and autodiff
    returns per-pod directions directly (sign methods + hier_sgd).

State layouts (``AlgoConfig.state_layout``): ``tree`` keeps the master
params as a pytree and applies updates per leaf; ``flat`` stores the
master (and delta / EF / momentum) AS the ``core.flatbuf`` buffer for the
entire run, materializing leaf views only at the loss boundary -- the
whole-model update is then one elementwise sweep, and under
``transport="fused"`` a single ``vote_update`` read-modify-write.  On a
mesh with a >1 model axis the flat buffer uses the *sharded* layout
(per-model-shard buckets) and every tree<->buffer move runs as a
``shard_map`` program (``core.shardflat``), so TP-sharded leaves are
never gathered -- the buffer lives model-axis sharded end to end, and
uneven extents (a model-sharded dim that does not divide the axis)
stay sharded too via the layout's padded blocks (``flatbuf`` padded-
shard rule; the zero tail is don't-care).  Both layouts are
bit-identical in trajectory (tests/test_parity_matrix.py, including
the uneven-leaf cell of the 8-device tier).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import clients as vclients
from repro.core import device_axis, flatbuf, schedule, shardflat, signs, votes
from repro.core.device_axis import LiftCfg
from repro.core.topology import Topology

PyTree = Any

SIGN_METHODS = ("hier_signsgd", "dc_hier_signsgd", "scaffold_hier_signsgd",
                "mtgc_hier_signsgd")
# methods whose clients apply a per-client control-variate / multi-
# timescale correction in the pre-sign slot (state: corr_cl + corr_edge)
CLIENT_CORRECTION_METHODS = ("scaffold_hier_signsgd", "mtgc_hier_signsgd")
ALL_METHODS = SIGN_METHODS + ("hier_sgd", "hier_local_qsgd")


@dataclasses.dataclass(frozen=True)
class AlgoConfig:
    method: str = "dc_hier_signsgd"
    mu: float = 1e-3                  # sign-step size
    mu_sgd: float = 0.1               # full-precision baseline step size
    t_e: int = 15                     # local steps per global round
    rho: float = 0.2                  # correction strength (DC)
    transport: str = "ag_packed"      # ag_packed (faithful) | ar_int8
                                      # | fused (flat-buffer, Pallas-backed)
    state_layout: str = "tree"        # tree (pytree master) | flat (master
                                      # lives AS the core.flatbuf buffer;
                                      # replicated regime only)
    anchor_staleness: int = 1         # 1 = paper's pipelined delta, 0 = fresh
                                      # (DC only; scaffold/mtgc corrections
                                      # are always refreshed fresh at the
                                      # round boundary)
    cloud_period: int = 2             # MTGC slow timescale: the cloud-level
                                      # eta term refreshes every cloud_period
                                      # rounds (the edge-level gamma term
                                      # refreshes every round)
    cloud_overlap: str = "sync"       # cloud sync schedule (core.schedule):
                                      # "sync" = issue+commit at the same
                                      # round boundary (the paper's barrier);
                                      # "overlap" = edges keep local-stepping
                                      # on their local models while the
                                      # cross-pod mean is in flight, commit
                                      # one boundary later (staged agg_next
                                      # slot; anchors refresh at the
                                      # committed, one-round-stale aggregate)
    clients: vclients.ClientConfig = vclients.ClientConfig()
                                      # virtual-client scale-out: K clients
                                      # per data slice, per-round sampling,
                                      # |D_qk| vote weights (replicated
                                      # regime only; the inactive default
                                      # is bitwise the legacy step)
    error_feedback: bool = False      # beyond-paper (replicated regime only)
    momentum: float = 0.0             # beyond-paper signum-style momentum
    compute_dtype: Any = jnp.bfloat16
    master_dtype: Any = jnp.float32
    delta_dtype: Any = jnp.bfloat16
    decay: bool = False               # mu_t = mu / sqrt(round + 1)

    def __post_init__(self):
        if self.method not in ALL_METHODS:
            raise ValueError(
                f"unknown method {self.method!r} (choose from "
                f"{', '.join(ALL_METHODS)})")
        if self.transport not in votes.SIGN_TRANSPORTS:
            raise ValueError(f"unknown transport {self.transport!r}")
        if self.state_layout not in ("tree", "flat"):
            raise ValueError(f"unknown state_layout {self.state_layout!r}")
        if self.cloud_period < 1:
            raise ValueError(
                f"cloud_period must be >= 1, got {self.cloud_period}")
        if self.cloud_overlap not in schedule.CLOUD_OVERLAP_MODES:
            raise ValueError(
                f"unknown cloud_overlap {self.cloud_overlap!r} (choose "
                f"from {', '.join(schedule.CLOUD_OVERLAP_MODES)})")

    @property
    def is_sign(self) -> bool:
        return self.method in SIGN_METHODS

    @property
    def is_dc(self) -> bool:
        return self.method == "dc_hier_signsgd"

    @property
    def is_scaffold(self) -> bool:
        return self.method == "scaffold_hier_signsgd"

    @property
    def is_mtgc(self) -> bool:
        return self.method == "mtgc_hier_signsgd"

    @property
    def has_client_correction(self) -> bool:
        """Per-client correction state in the pre-sign slot (corr_cl +
        corr_edge buffers): SCAFFOLD control variates or MTGC's
        multi-timescale terms."""
        return self.method in CLIENT_CORRECTION_METHODS

    @property
    def is_overlap(self) -> bool:
        return self.cloud_overlap == "overlap"

    @property
    def cloud_schedule(self) -> schedule.CloudSchedule:
        """The cloud sync schedule (issue/commit latency) this config
        selects -- the SAME object the ``ref_fed`` oracle consumes."""
        return schedule.CloudSchedule.from_mode(self.cloud_overlap)


class TrainState(NamedTuple):
    """Training state.  With ``state_layout="flat"`` the params / delta /
    ef / mom / corr entries are ``flatbuf.FlatState`` buffers ([P, n_pad]
    and [P, D, n_pad]) instead of pytrees; each optional entry is ``None``
    whenever the method / options do not read it (DC correction only for
    ``dc_hier_signsgd`` or the FSDP regime's lift plumbing; corr_cl /
    corr_edge only for the scaffold/mtgc client-correction methods)."""
    step: jax.Array                   # global step counter (t * T_E + tau)
    params: PyTree                    # [P, ...] per-pod edge models v_q
    agg_next: PyTree | None           # [P, ...] staged in-flight cloud
                                      #   aggregate (cloud_overlap=
                                      #   "overlap" only: issued at the
                                      #   previous boundary, committed at
                                      #   the next; FlatState [P, n_pad]
                                      #   under state_layout="flat")
    delta: PyTree | None              # [P, ...] active correction c - c_q
    delta_next: PyTree | None         # staged delta (anchor_staleness=1)
    ef: PyTree | None                 # [P, D*K, ...] error-feedback residual
    mom: PyTree | None                # [P, D*K, ...] sign-momentum buffer
    corr_cl: PyTree | None            # [P, D*K, ...] per-client correction:
                                      #   scaffold c_local / mtgc gamma_qk
    corr_edge: PyTree | None          # [P, ...] per-edge correction term:
                                      #   scaffold c_global (one pod-
                                      #   replicated copy) / mtgc eta_q
    rng: jax.Array                    # (K = clients per slice; K=1 default)


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    """What a model must provide to train under the hierarchy.

    loss(params, batch, rng) -> scalar  -- mean loss of ONE replica on ONE
        device batch (no leading P/D dims); cotangents through it are the
        paper's per-device gradients.
    compute_specs -- per-leaf PartitionSpec of the *leaf* dims during
        compute (TP layout).
    master_specs  -- per-leaf PartitionSpec of the master storage (equal to
        compute_specs in the replicated regime; includes 'data' for FSDP).
    loss_master(params, delta, batch, rngs, lift) -> (sum_loss, aux) --
        FSDP regime only: model applies ``lift`` per layer inside its scan.
    """
    loss: Callable[[PyTree, Any, jax.Array], jax.Array] | None
    compute_specs: PyTree
    master_specs: PyTree
    loss_master: Callable | None = None
    param_mode: str = "replicated"    # replicated | fsdp


def _bcast_pd(topo: Topology, tree: PyTree, specs: PyTree, dtype,
              devices: int | None = None) -> PyTree:
    return device_axis.broadcast_devices(topo, tree, specs, dtype,
                                         devices=devices)


def make_hier_step(topo: Topology, algo: AlgoConfig, bundle: ModelBundle,
                   sync: str = "cond"):
    """Build (init_fn, train_step).

    train_step(state, batch, edge_weights, dev_weights, dev_mask)
        -> (state, metrics)

    batch: {'train': pytree of [P, D, b, ...], 'anchor': optional same}.
    edge_weights: [P] = D_q/N;  dev_weights: [P, D] = |D_qk|/D_q;
    dev_mask: [P, D] float in {0,1} -- vote quorum / straggler mask --
        or, with an ACTIVE ``algo.clients``, optionally [P, D, K] per
        virtual client (the elastic Membership's client-granular
        liveness; multiplied into the per-round participation mask, so
        churn is a runtime value change, never a retrace).

    Virtual clients (``algo.clients``, replicated regime only): when the
    ClientConfig is *active*, each physical slice hosts K virtual
    clients -- the device batch is carved into K per-client shards and
    the client dim merges into the voter axis ([P, D*K, b/K, ...], a
    local reshape; ``core.clients``).  A per-round participation mask
    (pinned to (seed, step // T_E)) combines with ``dev_mask`` and with
    the config's integer data shares |D_qk| into (a) the weighted
    majority-vote weights -- tally range sum(w), empty quorum abstains
    -- and (b) the anchor/mean aggregation shares, renormalized to the
    participating clients each round (``dev_weights`` contributes the
    physical-slice factor).  The inactive default runs the exact legacy
    step: K=1 / full participation / unit weights is bitwise identical
    to the pre-virtual-client trajectory.

    sync: 'cond'  -- prologue under lax.cond on step % T_E (the driver);
          'always'/'never' -- statically include/skip the prologue (used by
          the dry-run so cost_analysis sees straight-line programs: a
          global round costs (T_E-1) x never + 1 x always).
    """
    t_e = algo.t_e
    fsdp = bundle.param_mode == "fsdp"
    flat = algo.state_layout == "flat"
    if flat and fsdp:
        raise ValueError(
            "state_layout='flat' requires the replicated regime (the FSDP "
            "lift votes per layer shard, so the whole-model buffer never "
            "forms)")
    cc = algo.clients
    virtual = cc.active
    if virtual and fsdp:
        raise ValueError(
            "virtual clients (clients count/participation/weights) require "
            "the replicated regime: the FSDP lift votes per layer shard "
            "with physical-device masks")
    if algo.has_client_correction and fsdp:
        raise ValueError(
            f"{algo.method} requires the replicated regime: its per-client "
            "correction state (corr_cl) rides the explicit voter axis, "
            "which the FSDP lift never materializes")
    if algo.is_overlap and fsdp:
        raise ValueError(
            "cloud_overlap='overlap' requires the replicated regime: the "
            "staged in-flight aggregate (agg_next) is a whole-model master "
            "snapshot, which the FSDP lift's per-layer-shard vote never "
            "materializes")
    if algo.is_overlap and sync == "never":
        raise ValueError(
            "cloud_overlap='overlap' needs the round prologue (issue + "
            "commit run there), which sync='never' statically removes; "
            "lower the local-step phase with a cloud_overlap='sync' config "
            "instead -- the local step is schedule-independent, so the "
            "program is identical")
    cloud_sched = algo.cloud_schedule
    # the merged voter axis: K virtual clients per physical data slice
    # (d_virtual == devices_per_pod on the inactive legacy path)
    d_virtual = topo.devices_per_pod * cc.count
    # streamed client sweep: loop the K clients inside the step instead
    # of widening the voter axis -- O(model/32 + tally) live memory,
    # bitwise identical to merged (the deferred-threshold tally
    # contract, see core.votes)
    stream = virtual and cc.mode == "stream"
    # merged full-precision aggregations re-associate their voter-axis
    # reduction to the streamed fold order (weighted_mean_dev clients=),
    # so BOTH modes share one trajectory per config
    k_merge = cc.count if virtual else 1
    vote_bound = (cc.weight_bound(topo.pods, topo.devices_per_pod)
                  if virtual else None)
    # DC correction state only exists where it is read: the DC method's
    # pre-sign correction, or the FSDP lift plumbing (which threads delta
    # through the loss for every method).
    needs_delta = fsdp or algo.is_dc
    vmap2 = lambda f: jax.vmap(jax.vmap(f))

    # ---------------- gradient machinery -------------------------------
    def per_device_grads(params, batch, rngs, devices=None):
        """Replicated regime: explicit [P, D, ...] per-(virtual-)device
        grads (the voter axis is the merged D*K extent when virtual
        clients are active -- the batch arrives already carved; the
        streamed sweep instead passes ``devices=devices_per_pod`` and a
        single client's [P, D, b/K, ...] batch slice)."""
        v_dev = _bcast_pd(topo, params, bundle.compute_specs,
                          algo.compute_dtype,
                          devices=d_virtual if devices is None else devices)

        def tot(vd):
            losses = vmap2(bundle.loss)(vd, batch, rngs)
            return jnp.sum(losses), losses

        g_dev, losses = jax.grad(tot, has_aux=True)(v_dev)
        return g_dev, losses

    def pod_direction_fsdp(params, delta, batch, rngs, maskf, devwf,
                           transport, rho):
        """FSDP regime: autodiff returns per-pod directions (vote/wmean)."""
        cfg = LiftCfg(topo=topo, transport=transport, rho=rho,
                      compute_dtype=algo.compute_dtype)
        lift = functools.partial(device_axis.fsdp_lift_tree, cfg,
                                 maskf=maskf, devwf=devwf)

        def tot(p):
            return bundle.loss_master(p, delta, batch, rngs, lift)

        direction, losses = jax.grad(tot, has_aux=True)(params)
        return direction, losses

    def pod_avg(tree, edge_w):
        return jax.tree.map(
            lambda v: votes.pod_weighted_average(topo, v, edge_w), tree)

    # shared per-leaf pieces of the local step -- used verbatim by BOTH
    # state layouts, so the bit-identical-trajectory contract between
    # them is maintained in one place
    def quantize_dev(g_dev, rngs):
        """Per-leaf unbiased ternary quantization (leaf-indexed rngs)."""
        leaves, treedef = jax.tree.flatten(g_dev)
        qleaves = []
        for i, g in enumerate(leaves):
            rr_pd = jax.vmap(jax.vmap(
                lambda k: jax.random.fold_in(k, i)))(rngs)
            qleaves.append(jax.vmap(jax.vmap(signs.ternary_quantize))(
                g.astype(jnp.float32), rr_pd))
        return treedef.unflatten(qleaves)

    def ef_residual(u_dev, s_dev, part=None):
        """e' = u - sent, scale = per-device mean |u| per leaf.

        A participating client transmitted ``scale * s``; a client
        masked out of the round (``part`` 0, virtual path only)
        transmitted NOTHING, so its residual carries the full
        direction forward (e' = u) -- the EF compensation contract."""
        def ef_upd(u, s):
            scale = jnp.mean(jnp.abs(u), axis=tuple(range(2, u.ndim)),
                             keepdims=True)
            sent = scale * s.astype(u.dtype)
            if part is not None:
                sent = sent * part.reshape(
                    part.shape + (1,) * (u.ndim - 2)).astype(u.dtype)
            return (u - sent).astype(jnp.float32)
        return jax.tree.map(ef_upd, u_dev, s_dev)

    def vote_direction(s_dev, vote_w):
        """Per-pod vote of a pre-signed tree via the configured
        transport; ``vote_w`` is the [P, D(*K)] voter mask (legacy) or
        the combined participation x |D_qk| integer weights."""
        if algo.transport == "fused":
            return votes.fused_sign_vote(topo, s_dev, None, 0.0, vote_w,
                                         specs=bundle.compute_specs)
        return jax.tree.map(
            lambda s, cs: votes.majority_vote_dev(
                topo, s, vote_w, algo.transport, cs,
                weight_bound=vote_bound),
            s_dev, bundle.compute_specs)

    # ---------------- anchor (DC) pass ----------------------------------
    # Parity contract note: the anchor is the one FULL-PRECISION
    # statistic the state layouts share.  On multi-chip TP meshes XLA
    # fuses the (large, scanned) gradient program differently around
    # the two layouts' consumers, so real archs can pick up f32-ULP
    # differences in delta between tree and flat state -- float-level
    # equivalence, same class as the FSDP-regime tolerance.  The toy
    # parity matrix (every mesh, incl. 2x2x2 TP) is exactly bitwise:
    # per-coordinate arithmetic is identical in both layouts, only XLA
    # fusion of the backward differs (an optimization_barrier on the
    # anchor grads was tried and does not pin it).
    def compute_delta(params, delta_shaped, batch, rngs, edge_w, dev_w,
                      maskf):
        if fsdp:
            # delta_shaped: values ignored (rho=0.0 in the anchor pass);
            # only its shapes matter to the model's lift plumbing.
            c_q, _ = pod_direction_fsdp(params, delta_shaped, batch,
                                        rngs, maskf, dev_w.astype(jnp.float32),
                                        "wmean", 0.0)
        elif stream:
            # streamed anchor: the same zeros-init K-term fold as the
            # local sweep (and as merged's weighted_mean_dev clients=
            # re-association), one client's grads live at a time.
            # dev_w arrives UNmerged here: [P, D, K] participating shares.
            pt = master_views(params) if flat else params
            p, d = topo.pods, topo.devices_per_pod
            rngs3 = rngs.reshape((p, d, cc.count) + rngs.shape[2:])
            if flat:
                acc0 = topo.constrain(
                    jnp.zeros((p, d, params.layout.n_pad), jnp.float32),
                    flat_spec(params.layout, 2))
            else:
                acc0 = jax.tree.map(
                    lambda v, cs: topo.constrain(
                        jnp.zeros((p, d) + v.shape[1:], jnp.float32),
                        topo.dev_spec(*cs)),
                    pt, bundle.compute_specs)

            def abody(c_idx, acc):
                b_c = vclients.client_slice(batch, cc.count, c_idx)
                r_c = jax.lax.dynamic_index_in_dim(rngs3, c_idx, axis=2,
                                                   keepdims=False)
                g_c, _ = per_device_grads(pt, b_c, r_c, devices=d)
                sh_c = jax.lax.dynamic_index_in_dim(dev_w, c_idx, axis=2,
                                                    keepdims=False)
                if flat:
                    g_buf = flatten_buf(params.layout, g_c, 2, jnp.float32)
                    return acc + g_buf * sh_c[:, :, None]
                return jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) * sh_c.reshape(
                        sh_c.shape + (1,) * (g.ndim - 2)), acc, g_c)

            acc = jax.lax.fori_loop(0, cc.count, abody, acc0)
            if flat:
                c_q = jnp.sum(acc, axis=1)
                c = votes.pod_weighted_average(topo, c_q, edge_w)
                delta = (c - c_q).astype(algo.delta_dtype)
                return constrain_master(flatbuf.FlatState(
                    delta,
                    flatbuf.with_dtype(params.layout, algo.delta_dtype)))
            c_q = jax.tree.map(lambda a: jnp.sum(a, axis=1), acc)
        elif flat:
            # the anchor stays flat: one weighted-mean + one pod
            # all-reduce over the whole-model buffer, and the delta the
            # local steps consume is the buffer itself (the pre-sign
            # correction u + rho*delta is one fused elementwise op).
            g_dev, _ = per_device_grads(master_views(params), batch, rngs)
            g_buf = flatten_buf(params.layout, g_dev, 2, jnp.float32)
            c_q = votes.weighted_mean_dev(topo, g_buf, dev_w,
                                          clients=k_merge)
            c = votes.pod_weighted_average(topo, c_q, edge_w)
            delta = (c - c_q).astype(algo.delta_dtype)
            return constrain_master(flatbuf.FlatState(
                delta, flatbuf.with_dtype(params.layout, algo.delta_dtype)))
        else:
            g_dev, _ = per_device_grads(params, batch, rngs)
            c_q = jax.tree.map(
                lambda g: votes.weighted_mean_dev(
                    topo, g.astype(jnp.float32), dev_w, clients=k_merge),
                g_dev)
        c = pod_avg(c_q, edge_w)
        delta = jax.tree.map(lambda a, b: (a - b).astype(algo.delta_dtype),
                             c, c_q)
        return constrain_master(delta)

    # ---------------- scaffold / mtgc correction refresh -----------------
    def compute_corrections(params, corr_cl, corr_edge, batch, rngs,
                            edge_w, dev_w, part, rnd_index):
        """Round-boundary refresh of the pre-sign client-correction state
        at the freshly aggregated params (always fresh -- the DC staging
        knob does not apply).  Every quantity below is built from the
        anchor gradients a_qk = grad f_qk(w^t) in f32, stored back in
        ``delta_dtype``.

        scaffold (option-I control variates): a participating client sets
          c_local_qk <- a_qk
        and the shared variate absorbs the weighted drift
          c_global <- c_global + sum_q ew_q sum_k sh_qk (a_qk - c_local_qk)
        -- telescoping under full participation.  An abstaining client
        carries c_local forward (the EF contract) and its zero
        participating share drops it from the drift sum.

        mtgc (multi-timescale): the edge-level term refreshes every round,
          gamma_qk <- c_q - a_qk,   c_q = sum_k sh_qk a_qk,
        the cloud-level term only every ``cloud_period`` rounds,
          eta_q <- c - c_q,         c = sum_q ew_q c_q.
        An edge whose whole quorum abstains keeps BOTH its terms for the
        round (its c_q is the empty sum); like DC's delta, c still sums
        the abstained edges' zero c_q -- documented semantics.

        ``dev_w``/``part`` arrive like ``compute_delta``'s: merged
        [P, D*K] participating shares / vote gate, or UNmerged [P, D, K]
        on the streamed path.  ``part=None`` (legacy, non-virtual) updates
        unconditionally, mirroring EF's carry-forward contract.
        """
        dd = algo.delta_dtype
        do_cloud = (rnd_index % algo.cloud_period) == 0

        if stream:
            return _corrections_stream(params, corr_cl, corr_edge, batch,
                                       rngs, edge_w, dev_w, part, do_cloud)

        # merged voter axis: all [P, D*K, ...] anchor grads at once; the
        # flat layout runs the SAME per-coordinate arithmetic on the
        # whole-model buffer (array leaves under the same tree.maps)
        pt = master_views(params) if flat else params
        g_dev, _ = per_device_grads(pt, batch, rngs)
        if flat:
            layout = params.layout
            a32 = flatten_buf(layout, g_dev, 2, jnp.float32)
            cl_old, ce_old = corr_cl.buf, corr_edge.buf
        else:
            a32 = jax.tree.map(lambda g: g.astype(jnp.float32), g_dev)
            cl_old, ce_old = corr_cl, corr_edge
        live = (jnp.ones((topo.pods,), bool) if part is None
                else jnp.any(part, axis=1))

        def gate(fresh, old):
            if part is None:
                return fresh
            return jax.tree.map(
                lambda f, o: jnp.where(
                    part.reshape(part.shape + (1,) * (f.ndim - 2)), f, o),
                fresh, old)

        def wmean(t):
            return jax.tree.map(
                lambda x: votes.weighted_mean_dev(topo, x, dev_w,
                                                  clients=k_merge), t)

        if algo.is_scaffold:
            upd_q = wmean(jax.tree.map(
                lambda a, c: a - c.astype(jnp.float32), a32, cl_old))
            drift = pod_avg(upd_q, edge_w)
            ce_new = jax.tree.map(
                lambda e, dr: (e.astype(jnp.float32) + dr).astype(dd),
                ce_old, drift)
            cl_new = gate(jax.tree.map(lambda a: a.astype(dd), a32), cl_old)
        else:  # mtgc
            c_q = wmean(a32)
            c = pod_avg(c_q, edge_w)
            eta = jax.tree.map(lambda u, v: (u - v).astype(dd), c, c_q)
            sel = do_cloud & live
            ce_new = jax.tree.map(
                lambda f, o: jnp.where(
                    sel.reshape((topo.pods,) + (1,) * (f.ndim - 1)), f, o),
                eta, ce_old)
            cl_new = gate(jax.tree.map(
                lambda cq, a: (cq[:, None] - a).astype(dd), c_q, a32),
                cl_old)
        if flat:
            return (corr_cl.replace(
                        topo.constrain(cl_new, flat_spec(layout, 2))),
                    constrain_master(corr_edge.replace(ce_new)))
        cl_new = jax.tree.map(
            lambda x, cs: topo.constrain(x, topo.dev_spec(*cs)),
            cl_new, bundle.compute_specs)
        return cl_new, constrain_master(ce_new)

    def _corrections_stream(params, corr_cl, corr_edge, batch, rngs,
                            edge_w, dev_w, part, do_cloud):
        """Streamed refresh: a fori_loop over clients folds the
        share-weighted anchor sums in the exact ``weighted_mean_dev
        clients=`` re-association (one client's grads live at a time) and
        writes per-client state in place.  MTGC needs c_q before gamma,
        so it recomputes the (deterministic) anchor grads in a second
        loop instead of stashing K f32 gradient copies -- live anchor
        memory stays O(model)."""
        dd = algo.delta_dtype
        p, d, k = topo.pods, topo.devices_per_pod, cc.count
        layout = params.layout if flat else None
        pt = master_views(params) if flat else params
        rngs3 = rngs.reshape((p, d, k) + rngs.shape[2:])
        live = (jnp.ones((p,), bool) if part is None
                else jnp.any(part, axis=(1, 2)))

        def grads_c(c_idx):
            b_c = vclients.client_slice(batch, k, c_idx)
            r_c = jax.lax.dynamic_index_in_dim(rngs3, c_idx, axis=2,
                                               keepdims=False)
            g_c, _ = per_device_grads(pt, b_c, r_c, devices=d)
            if flat:
                return flatten_buf(layout, g_c, 2, jnp.float32)
            return jax.tree.map(lambda g: g.astype(jnp.float32), g_c)

        def wmul(x, sh):          # x: [P, D, ...], sh: [P, D]
            return x * sh.reshape(sh.shape + (1,) * (x.ndim - 2))

        def sh_of(c_idx):
            return jax.lax.dynamic_index_in_dim(dev_w, c_idx, axis=2,
                                                keepdims=False)

        def gate_c(c_idx, fresh, old):
            if part is None:
                return fresh
            g = jax.lax.dynamic_index_in_dim(part, c_idx, axis=2,
                                             keepdims=False)
            return jax.tree.map(
                lambda f, o: jnp.where(
                    g.reshape(g.shape + (1,) * (f.ndim - 2)), f, o),
                fresh, old)

        # [P, D, K, ...] views of the per-client slot (array leaf for the
        # flat layout -- the tree.maps below treat both uniformly)
        if flat:
            cl3 = corr_cl.buf.reshape(p, d, k, layout.n_pad)
            acc0 = topo.constrain(
                jnp.zeros((p, d, layout.n_pad), jnp.float32),
                flat_spec(layout, 2))
        else:
            cl3 = jax.tree.map(
                lambda x: x.reshape((p, d, k) + x.shape[2:]), corr_cl)
            acc0 = jax.tree.map(
                lambda v, cs: topo.constrain(
                    jnp.zeros((p, d) + v.shape[1:], jnp.float32),
                    topo.dev_spec(*cs)),
                pt, bundle.compute_specs)

        def take3(t3, c_idx):
            return jax.tree.map(
                lambda x: jax.lax.dynamic_index_in_dim(
                    x, c_idx, axis=2, keepdims=False), t3)

        def put3(t3, tc, c_idx):
            return jax.tree.map(
                lambda x3, xc: jax.lax.dynamic_update_index_in_dim(
                    x3, xc, c_idx, axis=2), t3, tc)

        ce_old = corr_edge.buf if flat else corr_edge
        if algo.is_scaffold:
            # one pass: fold the share-weighted drift (a - c_local) and
            # refresh participating clients' c_local in place
            def body(c_idx, carry):
                acc2, cl3_c = carry
                a_c = grads_c(c_idx)
                sh = sh_of(c_idx)
                cl_c = take3(cl3_c, c_idx)
                acc2 = jax.tree.map(
                    lambda a2, a, cv: a2 + wmul(
                        a - cv.astype(jnp.float32), sh),
                    acc2, a_c, cl_c)
                fresh = gate_c(c_idx,
                               jax.tree.map(lambda a: a.astype(dd), a_c),
                               cl_c)
                return acc2, put3(cl3_c, fresh, c_idx)

            acc2, cl3 = jax.lax.fori_loop(0, k, body, (acc0, cl3))
            upd_q = jax.tree.map(lambda a: jnp.sum(a, axis=1), acc2)
            drift = pod_avg(upd_q, edge_w)
            ce_new = jax.tree.map(
                lambda e, dr: (e.astype(jnp.float32) + dr).astype(dd),
                ce_old, drift)
        else:  # mtgc: pass 1 folds c_q, pass 2 writes gamma per client
            def body(c_idx, acc):
                return jax.tree.map(
                    lambda a0, a: a0 + wmul(a, sh_of(c_idx)),
                    acc, grads_c(c_idx))

            acc = jax.lax.fori_loop(0, k, body, acc0)
            c_q = jax.tree.map(lambda a: jnp.sum(a, axis=1), acc)
            c = pod_avg(c_q, edge_w)
            eta = jax.tree.map(lambda u, v: (u - v).astype(dd), c, c_q)
            sel = do_cloud & live
            ce_new = jax.tree.map(
                lambda f, o: jnp.where(
                    sel.reshape((p,) + (1,) * (f.ndim - 1)), f, o),
                eta, ce_old)

            def body2(c_idx, cl3_c):
                a_c = grads_c(c_idx)
                fresh = jax.tree.map(
                    lambda cq, a: (cq[:, None] - a).astype(dd), c_q, a_c)
                fresh = gate_c(c_idx, fresh, take3(cl3_c, c_idx))
                return put3(cl3_c, fresh, c_idx)

            cl3 = jax.lax.fori_loop(0, k, body2, cl3)

        cl_t = jax.tree.map(
            lambda x: x.reshape((p, d * k) + x.shape[3:]), cl3)
        if flat:
            return (corr_cl.replace(
                        topo.constrain(cl_t, flat_spec(layout, 2))),
                    constrain_master(corr_edge.replace(ce_new)))
        cl_t = jax.tree.map(
            lambda x, cs: topo.constrain(x, topo.dev_spec(*cs)),
            cl_t, bundle.compute_specs)
        return cl_t, constrain_master(ce_new)

    def client_correction_dev(corr_cl, corr_edge):
        """[P, D*K, ...] per-client pre-sign correction in delta_dtype:
        scaffold q = c_global - c_local ; mtgc q = gamma + eta -- the
        merged-voter-axis analogue of DC's shared delta broadcast.  Never
        folded into the fused kernel (the kernel's fold is one SHARED
        delta); instead it pre-adds into u_dev like the DC non-fold path.
        """
        cl = (shardflat.tree_views(topo, corr_cl, cast=False)
              if flat else corr_cl)
        ce = (shardflat.tree_views(topo, corr_edge, cast=False)
              if flat else corr_edge)
        ce_dev = _bcast_pd(topo, ce, bundle.compute_specs, None,
                           devices=d_virtual)
        if algo.is_scaffold:
            return jax.tree.map(lambda e, cv: e - cv, ce_dev, cl)
        return jax.tree.map(lambda cv, e: cv + e, cl, ce_dev)

    def flat_spec(layout, lead: int = 1):
        """Buffer spec (model-axis sharded iff the layout is) -- the
        single source of truth is ``shardflat.buf_spec`` so train-state
        placement can never diverge from the shard_map in/out specs."""
        return shardflat.buf_spec(topo, layout, batch_dims=lead)

    def constrain_master(tree):
        if flat:   # FlatState: [P, n_pad] buffer (sharded iff its layout)
            return tree.replace(
                topo.constrain(tree.buf, flat_spec(tree.layout)))
        return jax.tree.map(
            lambda x, s: topo.constrain(x, topo.pod_spec(*s)),
            tree, bundle.master_specs)

    def master_views(fs):
        """Flat state -> leaf views, re-constrained to the per-leaf master
        layout so the loss compiles to the SAME partitioned compute as the
        tree layout (keeps flat bit-identical to tree under TP sharding).
        Sharded layouts slice the views inside shard_map -- no model-axis
        gather; the re-constrain is then a no-op for sharded leaves."""
        return jax.tree.map(
            lambda x, s: topo.constrain(x, topo.pod_spec(*s)),
            shardflat.tree_views(topo, fs), bundle.master_specs)

    def gather_leafdims(tree, lead):
        """Replicate every leaf's non-leading dims before an *unsharded*
        flat-buffer concat: uniform operand shardings keep XLA's concat
        partitioner out of the mixed minor-/major-dim-sharded case it
        miscompiles.  Sharded layouts never come through here -- their
        concats are rank-local inside shard_map (``flatten_buf``)."""
        spec = topo.dev_spec if lead == 2 else topo.pod_spec
        return jax.tree.map(
            lambda x: topo.constrain(x, spec(*([None] * (x.ndim - lead)))),
            tree)

    def flatten_buf(layout, tree, batch_dims, dtype=None):
        """tree -> flat buffer without unsharding TP leaves: per-bucket
        shard_map writes for sharded layouts, the ``gather_leafdims``
        dodge for the unsharded one."""
        if layout.shards > 1:
            return shardflat.flatten(topo, layout, tree, batch_dims, dtype)
        return flatbuf.flatten_tree(layout, gather_leafdims(tree, batch_dims),
                                    batch_dims=batch_dims, dtype=dtype)

    # ---------------- local step direction ------------------------------
    def local_direction(state, params, delta, corr_cl, corr_edge, batch,
                        rngs, dev_w, vote_w, maskf):
        """-> (direction [P,...], new_ef, new_mom, losses).

        dev_w: [P, D(*K)] aggregation shares (participating shares when
        virtual); vote_w: voter mask / integer vote weights; maskf: the
        physical [P, D] float mask (FSDP regime only)."""
        if fsdp:
            transport = (algo.transport if algo.is_sign else "wmean")
            rho = algo.rho if algo.is_dc else 0.0
            direction, losses = pod_direction_fsdp(
                params, delta, batch, rngs, maskf,
                dev_w.astype(jnp.float32), transport, rho)
            return direction, state.ef, state.mom, losses

        g_dev, losses = per_device_grads(params, batch, rngs)
        new_ef, new_mom = state.ef, state.mom

        if algo.method == "hier_sgd":
            direction = jax.tree.map(
                lambda g: votes.weighted_mean_dev(
                    topo, g.astype(jnp.float32), dev_w, clients=k_merge),
                g_dev)
        elif algo.method == "hier_local_qsgd":
            direction = jax.tree.map(
                lambda g: votes.weighted_mean_dev(topo, g, dev_w,
                                                  clients=k_merge),
                quantize_dev(g_dev, rngs))
        else:  # sign methods
            u_dev = g_dev
            if algo.momentum > 0.0:
                new_mom = jax.tree.map(
                    lambda m, g: algo.momentum * m
                    + (1.0 - algo.momentum) * g.astype(m.dtype),
                    state.mom, g_dev)
                u_dev = new_mom
            if algo.error_feedback:
                u_dev = jax.tree.map(
                    lambda u, e: u.astype(jnp.float32) + e, u_dev, state.ef)
            # the fused flat-buffer transport folds the DC correction
            # pre-sign into its single device-side sweep (Alg. 2's
            # sgn(g + rho*delta), same arithmetic => bit-identical); the
            # EF update needs the explicit per-leaf signs, so EF runs
            # the tree path up to the vote.
            fold_dc = (algo.transport == "fused" and algo.is_dc
                       and not algo.error_feedback)
            if algo.is_dc and not fold_dc:
                d_dev = _bcast_pd(topo, delta, bundle.compute_specs, None,
                                  devices=d_virtual)
                u_dev = jax.tree.map(
                    lambda u, dl: u + algo.rho * dl.astype(u.dtype),
                    u_dev, d_dev)
            if algo.has_client_correction:
                q_dev = client_correction_dev(corr_cl, corr_edge)
                u_dev = jax.tree.map(
                    lambda u, ql: u + algo.rho * ql.astype(u.dtype),
                    u_dev, q_dev)
            if algo.transport == "fused" and not algo.error_feedback:
                direction = votes.fused_sign_vote(
                    topo, u_dev, delta if fold_dc else None,
                    algo.rho if fold_dc else 0.0, vote_w,
                    specs=bundle.compute_specs)
                return direction, new_ef, new_mom, losses
            s_dev = jax.tree.map(signs.sgn, u_dev)
            if algo.error_feedback:
                new_ef = ef_residual(u_dev, s_dev,
                                     part=(vote_w > 0) if virtual else None)
            direction = vote_direction(s_dev, vote_w)
        return direction, new_ef, new_mom, losses

    # ---------------- flat-state local step -----------------------------
    def local_step_flat(state, params, delta, corr_cl, corr_edge, batch,
                        rngs, dev_w, vote_w, mu):
        """state_layout='flat': whole-buffer update, no per-leaf loops.

        params/delta are ``flatbuf.FlatState``; returns the *updated*
        params (the fused transport applies v <- v - mu*vote inside its
        single ``vote_update`` read-modify-write; every other direction
        is flattened once and applied as one elementwise sweep).
        Per-coordinate arithmetic matches the tree path exactly, so the
        trajectory is bit-identical leaf-for-leaf.
        """
        layout = params.layout
        g_dev, losses = per_device_grads(master_views(params), batch, rngs)
        new_ef, new_mom = state.ef, state.mom

        def descend(direction_tree):
            dir_buf = flatten_buf(layout, direction_tree, 1,
                                  params.buf.dtype)
            return params.replace(params.buf - mu * dir_buf)

        if algo.method == "hier_sgd":
            g_buf = flatten_buf(layout, g_dev, 2, jnp.float32)
            dir_buf = votes.weighted_mean_dev(topo, g_buf, dev_w,
                                              clients=k_merge)
            new_params = params.replace(
                params.buf - mu * dir_buf.astype(params.buf.dtype))
            return new_params, new_ef, new_mom, losses
        if algo.method == "hier_local_qsgd":
            # quantize per leaf BEFORE flattening (identical fold_in
            # indices AND identical norm-reduction sharding to the tree
            # path), then one whole-buffer weighted mean + update
            q_buf = flatten_buf(layout, quantize_dev(g_dev, rngs), 2,
                                jnp.float32)
            dir_buf = votes.weighted_mean_dev(topo, q_buf, dev_w,
                                              clients=k_merge)
            new_params = params.replace(
                params.buf - mu * dir_buf.astype(params.buf.dtype))
            return new_params, new_ef, new_mom, losses

        # sign methods
        u_dev = g_dev
        if algo.momentum > 0.0:
            g_buf = flatten_buf(layout, g_dev, 2, jnp.float32)
            new_mom = state.mom.replace(
                algo.momentum * state.mom.buf
                + (1.0 - algo.momentum) * g_buf)
            u_dev = shardflat.tree_views(topo, new_mom, cast=False)
        if algo.error_feedback:
            # the EF scale is a per-leaf mean: constrain u to the tree
            # path's compute sharding so the reduction order (and hence
            # the residual) stays bitwise identical
            u_dev = jax.tree.map(
                lambda u, e, cs: topo.constrain(
                    u.astype(jnp.float32) + e, topo.dev_spec(*cs)),
                u_dev, shardflat.tree_views(topo, state.ef, cast=False),
                bundle.compute_specs)
        fold_dc = (algo.transport == "fused" and algo.is_dc
                   and not algo.error_feedback)
        if algo.is_dc and not fold_dc:
            d_dev = _bcast_pd(topo, shardflat.tree_views(topo, delta,
                                                         cast=False),
                              bundle.compute_specs, None,
                              devices=d_virtual)
            u_dev = jax.tree.map(
                lambda u, dl: u + algo.rho * dl.astype(u.dtype),
                u_dev, d_dev)
        if algo.has_client_correction:
            q_dev = client_correction_dev(corr_cl, corr_edge)
            u_dev = jax.tree.map(
                lambda u, ql: u + algo.rho * ql.astype(u.dtype),
                u_dev, q_dev)
        if algo.transport == "fused" and not algo.error_feedback:
            # the whole-model v <- v - mu*vote is ONE vote_update
            # read-modify-write over the packed-word buffer (mu folded
            # into the kernel when it is step-independent)
            new_buf = votes.fused_sign_vote_update(
                topo, layout, u_dev,
                delta.buf if fold_dc else None,
                algo.rho if fold_dc else 0.0, vote_w, params.buf, mu,
                mu_static=None if algo.decay else algo.mu)
            return params.replace(new_buf), new_ef, new_mom, losses
        s_dev = jax.tree.map(signs.sgn, u_dev)
        if algo.error_feedback:
            new_ef = state.ef.replace(flatten_buf(
                layout,
                ef_residual(u_dev, s_dev,
                            part=(vote_w > 0) if virtual else None),
                2, jnp.float32))
        return descend(vote_direction(s_dev, vote_w)), new_ef, new_mom, losses

    # ---------------- streamed-client local step ------------------------
    def local_step_stream(state, params, delta, corr_cl, corr_edge, batch,
                          rngs, shares3, vote_w3, mu):
        """ClientConfig.mode='stream': fori_loop over the K virtual
        clients with only ONE client's gradient live at a time.

        Per client the (DC-corrected) direction is sign-compressed and
        accumulated into a persistent signed tally (``votes`` tally
        machinery, Pallas ``tally_acc`` RMW on the fused path); the sign
        threshold is deferred to after the loop, where ``t >= 0``
        reproduces merged's ``2*pos >= n_eff`` tie rule exactly --
        integer tallies, so the trajectory is bitwise identical to the
        merged voter-axis step in BOTH state layouts.  shares3/vote_w3
        arrive UNmerged: [P, D, K].  Returns the *updated* params like
        ``local_step_flat``.
        """
        k = cc.count
        p, d = topo.pods, topo.devices_per_pod
        layout = params.layout if flat else None
        params_tree = master_views(params) if flat else params
        rngs3 = rngs.reshape((p, d, k) + rngs.shape[2:])
        fuse = (algo.is_sign and algo.transport == "fused"
                and not algo.error_feedback)
        fold_dc = fuse and algo.is_dc
        acc_dt = votes.tally_dtype(vote_bound)

        # the shared DC correction broadcasts ONCE (physical device axis
        # only); clients re-read it each iteration
        delta_tree = None
        if algo.is_dc and not fold_dc and algo.is_sign:
            dt = (shardflat.tree_views(topo, delta, cast=False)
                  if flat else delta)
            delta_tree = _bcast_pd(topo, dt, bundle.compute_specs, None,
                                   devices=d)
        # ... and so does the scaffold/mtgc edge-level term; the
        # per-client term (corr3) is sliced per client inside the loop
        ce_tree = corr3 = None
        if algo.has_client_correction:
            ce = (shardflat.tree_views(topo, corr_edge, cast=False)
                  if flat else corr_edge)
            ce_tree = _bcast_pd(topo, ce, bundle.compute_specs, None,
                                devices=d)

        # per-voter state views sliced per client inside the loop
        def views3(fs_or_tree):
            t = (shardflat.tree_views(topo, fs_or_tree, cast=False)
                 if flat else fs_or_tree)
            return jax.tree.map(
                lambda x: x.reshape((p, d, k) + x.shape[2:]), t)

        ef3 = views3(state.ef) if algo.error_feedback else None
        mom3 = views3(state.mom) if algo.momentum > 0.0 else None
        if algo.has_client_correction:
            corr3 = views3(corr_cl)

        def take_c(tree, c_idx):
            return jax.tree.map(
                lambda x: jax.lax.dynamic_index_in_dim(
                    x, c_idx, axis=2, keepdims=False), tree)

        def put_c(tree3, tree_c, c_idx):
            return jax.tree.map(
                lambda x3, xc: jax.lax.dynamic_update_index_in_dim(
                    x3, xc, c_idx, axis=2), tree3, tree_c)

        # the persistent accumulator: an integer sign tally for sign
        # methods (flat words buffer on the pure-fused path, per-leaf
        # otherwise), an f32 share-weighted sum for the mean methods
        tally_flat = tally_tree = acc = None
        vlayout = None
        if not algo.is_sign:
            if flat:
                acc = topo.constrain(
                    jnp.zeros((p, d, layout.n_pad), jnp.float32),
                    flat_spec(layout, 2))
            else:
                acc = jax.tree.map(
                    lambda v, cs: topo.constrain(
                        jnp.zeros((p, d) + v.shape[1:], jnp.float32),
                        topo.dev_spec(*cs)),
                    params_tree, bundle.compute_specs)
        elif fuse:
            if flat:
                vlayout = layout
            else:
                # a layout over the per-device direction shapes (only
                # shapes matter -- packing is dtype-blind past the sign)
                template = jax.tree.map(
                    lambda v: jax.ShapeDtypeStruct(
                        (p, d) + v.shape[1:], jnp.float32), params_tree)
                if topo.model_shards > 1:
                    lay = flatbuf.make_layout(
                        template, batch_dims=2,
                        sharding=shardflat.model_sharding(
                            topo, bundle.compute_specs))
                    vlayout = lay if lay.shards > 1 else None
                if vlayout is None:
                    vlayout = flatbuf.make_layout(template, batch_dims=2)
            tally_flat = topo.constrain(
                jnp.zeros((p, d, vlayout.n_pad), acc_dt),
                shardflat.buf_spec(topo, vlayout, 2))
        else:
            tally_tree = jax.tree.map(
                lambda v, cs: topo.constrain(
                    jnp.zeros((p, d) + v.shape[1:], acc_dt),
                    topo.dev_spec(*cs)),
                params_tree, bundle.compute_specs)

        losses0 = jnp.zeros((p, d, k), jnp.float32)

        def body(c_idx, carry):
            tally_f, tally_t, acc_c, ef_c, mom_c, loss_c = carry
            b_c = vclients.client_slice(batch, k, c_idx)
            r_c = jax.lax.dynamic_index_in_dim(rngs3, c_idx, axis=2,
                                               keepdims=False)
            g_c, losses = per_device_grads(params_tree, b_c, r_c, devices=d)
            loss_c = jax.lax.dynamic_update_index_in_dim(
                loss_c, losses.astype(jnp.float32), c_idx, axis=2)
            sh_c = jax.lax.dynamic_index_in_dim(shares3, c_idx, axis=2,
                                                keepdims=False)
            w_c = jax.lax.dynamic_index_in_dim(vote_w3, c_idx, axis=2,
                                               keepdims=False)

            if not algo.is_sign:
                if algo.method == "hier_local_qsgd":
                    g_c = quantize_dev(g_c, r_c)
                if flat:
                    g_buf = flatten_buf(layout, g_c, 2, jnp.float32)
                    acc_c = acc_c + g_buf * sh_c[:, :, None]
                else:
                    acc_c = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32)
                        * sh_c.reshape(sh_c.shape + (1,) * (g.ndim - 2)),
                        acc_c, g_c)
                return (tally_f, tally_t, acc_c, ef_c, mom_c, loss_c)

            u_c = g_c
            if algo.momentum > 0.0:
                m_new = jax.tree.map(
                    lambda m, g: algo.momentum * m
                    + (1.0 - algo.momentum) * g.astype(m.dtype),
                    take_c(mom_c, c_idx), g_c)
                mom_c = put_c(mom_c, m_new, c_idx)
                u_c = m_new
            if algo.error_feedback:
                e_c = take_c(ef_c, c_idx)
                if flat:
                    u_c = jax.tree.map(
                        lambda u, e, cs: topo.constrain(
                            u.astype(jnp.float32) + e, topo.dev_spec(*cs)),
                        u_c, e_c, bundle.compute_specs)
                else:
                    u_c = jax.tree.map(
                        lambda u, e: u.astype(jnp.float32) + e, u_c, e_c)
            if delta_tree is not None:
                u_c = jax.tree.map(
                    lambda u, dl: u + algo.rho * dl.astype(u.dtype),
                    u_c, delta_tree)
            if ce_tree is not None:
                cl_c = take_c(corr3, c_idx)
                if algo.is_scaffold:
                    q_c = jax.tree.map(lambda e, cv: e - cv, ce_tree, cl_c)
                else:
                    q_c = jax.tree.map(lambda cv, e: cv + e, cl_c, ce_tree)
                u_c = jax.tree.map(
                    lambda u, ql: u + algo.rho * ql.astype(u.dtype),
                    u_c, q_c)
            if fuse:
                tally_f = votes.fused_sign_tally_accumulate(
                    topo, vlayout, u_c,
                    delta if (fold_dc and not flat) else None,
                    delta.buf if (fold_dc and flat) else None,
                    algo.rho if fold_dc else 0.0, w_c, tally_f)
            else:
                s_c = jax.tree.map(signs.sgn, u_c)
                if algo.error_feedback:
                    ef_c = put_c(ef_c,
                                 ef_residual(u_c, s_c, part=(w_c > 0)),
                                 c_idx)
                tally_t = jax.tree.map(
                    lambda t, s: votes.tally_add_signs(t, s, w_c),
                    tally_t, s_c)
            return (tally_f, tally_t, acc_c, ef_c, mom_c, loss_c)

        tally_flat, tally_tree, acc, ef3, mom3, losses3 = jax.lax.fori_loop(
            0, k, body, (tally_flat, tally_tree, acc, ef3, mom3, losses0))
        losses = losses3.reshape(p, d * k)

        new_ef, new_mom = state.ef, state.mom
        if ef3 is not None:
            ef_t = jax.tree.map(
                lambda x: x.reshape((p, d * k) + x.shape[3:]), ef3)
            new_ef = (state.ef.replace(
                flatten_buf(layout, ef_t, 2, jnp.float32))
                if flat else ef_t)
        if mom3 is not None:
            mom_t = jax.tree.map(
                lambda x: x.reshape((p, d * k) + x.shape[3:]), mom3)
            new_mom = (state.mom.replace(
                flatten_buf(layout, mom_t, 2, jnp.float32))
                if flat else mom_t)

        if not algo.is_sign:
            if flat:
                dir_buf = jnp.sum(acc, axis=1)
                new_params = params.replace(
                    params.buf - mu * dir_buf.astype(params.buf.dtype))
            else:
                direction = jax.tree.map(lambda a: jnp.sum(a, axis=1), acc)
                new_params = jax.tree.map(
                    lambda v, s: v - mu * s.astype(v.dtype), params,
                    direction)
            return new_params, new_ef, new_mom, losses

        # deferred threshold: t >= 0 -> +1 (== merged's 2*pos >= n_eff),
        # empty quorum (n_eff == 0) abstains
        n_eff = jnp.sum(vote_w3.astype(jnp.int32), axis=(1, 2))
        if fuse:
            if flat:
                new_buf = votes.fused_tally_finish(
                    topo, vlayout, tally_flat, n_eff, params.buf, mu)
                new_params = params.replace(new_buf)
            else:
                direction = votes.fused_tally_finish(
                    topo, vlayout, tally_flat, n_eff, None, None)
                new_params = jax.tree.map(
                    lambda v, s: v - mu * s.astype(v.dtype), params,
                    direction)
        else:
            direction = jax.tree.map(
                lambda t, cs: votes.tally_vote_dev(topo, t, n_eff, cs),
                tally_tree, bundle.compute_specs)
            if flat:
                dir_buf = flatten_buf(layout, direction, 1,
                                      params.buf.dtype)
                new_params = params.replace(params.buf - mu * dir_buf)
            else:
                new_params = jax.tree.map(
                    lambda v, s: v - mu * s.astype(v.dtype), params,
                    direction)
        return new_params, new_ef, new_mom, losses

    # ---------------- the step ------------------------------------------
    def train_step(state: TrainState, batch, edge_weights, dev_weights,
                   dev_mask):
        rng, r_local, r_anchor = jax.random.split(state.rng, 3)
        pd = (topo.pods, d_virtual)
        rngs_l = jax.random.split(r_local, pd[0] * pd[1])
        rngs_l = rngs_l.reshape(pd + rngs_l.shape[1:])
        rngs_a = jax.random.split(r_anchor, pd[0] * pd[1])
        rngs_a = rngs_a.reshape(pd + rngs_a.shape[1:])
        maskf = dev_mask.astype(jnp.float32)
        if maskf.ndim == 3 and not virtual:
            raise ValueError(
                "a client-granular [P, D, K] dev_mask requires an ACTIVE "
                "AlgoConfig.clients (the virtual-client path); the legacy "
                "path takes the [P, D] device mask")
        rnd_index = state.step // t_e
        if virtual:
            # per-round participation (pinned to (seed, round), so the
            # anchor pass and every local step of round t -- and a
            # checkpoint restored mid-round -- see the same quorum),
            # combined with the caller's membership mask: [P, D] device
            # granularity, or [P, D, K] per virtual client (elastic
            # Membership churn -- a value change, never a retrace)
            if maskf.ndim == 3 and maskf.shape[2] != cc.count:
                raise ValueError(
                    f"dev_mask client dim {maskf.shape[2]} != K={cc.count}")
            maskf3 = maskf if maskf.ndim == 3 else maskf[:, :, None]
            part = vclients.participation_mask(
                cc, topo.pods, topo.devices_per_pod, rnd_index)
            part = topo.constrain(part * maskf3,
                                  topo.client_spec())         # [P, D, K]
            w_arr = cc.weight_array(topo.pods, topo.devices_per_pod)
            # weighted popcount weights: pure int32 arithmetic, so
            # |D_qk| shares above 2^24 never round through float ...
            vote_w3 = (jnp.asarray(w_arr, jnp.int32)
                       * part.astype(jnp.int32))                # [P, D, K]
            vote_w = vote_w3.reshape(pd)
            # ... and participating aggregation shares for anchor/means
            shares = vclients.participating_shares(
                dev_weights, jnp.asarray(w_arr, jnp.float32), part)
            if stream:
                # the streamed sweep slices clients itself -- the batch
                # stays [P, D, b, ...] and weights stay [P, D, K]
                shares3 = shares.reshape(
                    topo.pods, topo.devices_per_pod, cc.count)
                carve = lambda b: b
            else:
                carve = lambda b: vclients.carve_batch(b, cc.count)
            # participation gate for the correction-state refresh --
            # same contract as EF: only clients with a live vote update
            corr_part = (vote_w3 > 0) if stream else (vote_w > 0)
        else:
            vote_w = maskf > 0.5
            shares = dev_weights
            carve = lambda b: b
            corr_part = None          # legacy path updates unconditionally
        train_batch = carve(batch["train"])
        anchor_batch = carve(batch.get("anchor", batch["train"]))
        agg_shares = shares3 if stream else shares

        # -- prologue: cloud issue/commit + anchor/correction refresh at
        # round start.  The schedule layer (core.schedule) decides what
        # "issue" and "commit" mean: sync commits the freshly issued
        # aggregate at the same boundary (today's barrier, bitwise);
        # overlap commits the aggregate issued at the PREVIOUS boundary
        # and stages this one in agg_next, so the anchors below refresh
        # at the committed (one-round-stale) model.
        def prologue(op):
            params, agg_next, delta, delta_next, corr_cl, corr_edge = op
            issued = constrain_master(pod_avg(params, edge_weights))
            params, agg_next = cloud_sched.commit(issued, agg_next)
            if algo.is_dc:
                fresh = compute_delta(params, delta, anchor_batch, rngs_a,
                                      edge_weights, agg_shares, maskf)
                if algo.anchor_staleness == 1:
                    delta, delta_next = delta_next, fresh
                else:
                    delta = fresh
            if algo.has_client_correction:
                corr_cl, corr_edge = compute_corrections(
                    params, corr_cl, corr_edge, anchor_batch, rngs_a,
                    edge_weights, agg_shares, corr_part, rnd_index)
            return params, agg_next, delta, delta_next, corr_cl, corr_edge

        def no_op(op):
            return op

        operand = (state.params, state.agg_next, state.delta,
                   state.delta_next, state.corr_cl, state.corr_edge)
        if sync == "cond":
            (params, agg_next, delta, delta_next, corr_cl,
             corr_edge) = jax.lax.cond(
                state.step % t_e == 0, prologue, no_op, operand)
        elif sync == "always":
            (params, agg_next, delta, delta_next, corr_cl,
             corr_edge) = prologue(operand)
        else:  # 'never'
            (params, agg_next, delta, delta_next, corr_cl,
             corr_edge) = operand

        mu = jnp.asarray(
            algo.mu if algo.is_sign else algo.mu_sgd, algo.master_dtype)
        if algo.decay:
            mu = mu / jnp.sqrt(rnd_index.astype(algo.master_dtype) + 1.0)

        # -- local sign step
        if stream:
            params, new_ef, new_mom, losses = local_step_stream(
                state, params, delta, corr_cl, corr_edge, train_batch,
                rngs_l, shares3, vote_w3, mu)
        elif flat:
            params, new_ef, new_mom, losses = local_step_flat(
                state, params, delta, corr_cl, corr_edge, train_batch,
                rngs_l, shares, vote_w, mu)
        else:
            direction, new_ef, new_mom, losses = local_direction(
                state, params, delta, corr_cl, corr_edge, train_batch,
                rngs_l, shares, vote_w, maskf)
            params = jax.tree.map(
                lambda v, s: v - mu * s.astype(v.dtype), params, direction)
        params = constrain_master(params)

        new_state = TrainState(
            step=state.step + 1, params=params, agg_next=agg_next,
            delta=delta, delta_next=delta_next, ef=new_ef, mom=new_mom,
            corr_cl=corr_cl, corr_edge=corr_edge, rng=rng)
        metrics = {
            "loss": jnp.mean(losses.astype(jnp.float32)),
            "loss_per_pod": jnp.mean(losses.astype(jnp.float32), axis=1),
            "mu": mu,
        }
        return new_state, metrics

    # ---------------- init ----------------------------------------------
    def init_fn(params_single: PyTree, rng: jax.Array) -> TrainState:
        """params_single: one replica's params (no leading dims)."""
        p = topo.pods

        def rep(x, s):
            xp = jnp.broadcast_to(x[None], (p,) + x.shape)
            return topo.constrain(
                xp.astype(algo.master_dtype)
                if jnp.issubdtype(x.dtype, jnp.floating) else xp,
                topo.pod_spec(*s))

        params_tree = jax.tree.map(rep, params_single, bundle.master_specs)
        if flat:
            # on a >1 model axis the buffer is laid out as per-shard
            # buckets and stays model-sharded for the whole run
            sharding = (shardflat.model_sharding(topo, bundle.master_specs)
                        if topo.model_shards > 1 else None)
            layout = flatbuf.make_layout(params_tree, batch_dims=1,
                                         sharding=sharding)
            buf = flatten_buf(layout, params_tree, 1)
            params = flatbuf.FlatState(
                topo.constrain(buf, flat_spec(layout)), layout)
            zeros_m = lambda dt: flatbuf.FlatState(
                topo.constrain(jnp.zeros((p, layout.n_pad), dt),
                               flat_spec(layout)),
                flatbuf.with_dtype(layout, dt))
            # per-voter buffers (EF / momentum) span the merged
            # virtual-client axis
            zeros_pd = lambda dt: flatbuf.FlatState(
                topo.constrain(jnp.zeros((p, d_virtual, layout.n_pad), dt),
                               flat_spec(layout, 2)),
                flatbuf.with_dtype(layout, dt), batch_dims=2)
        else:
            params = params_tree
            zeros_m = lambda dt: constrain_master(jax.tree.map(
                lambda v: jnp.zeros_like(v, dtype=dt), params_tree))
            zeros_pd = lambda dt: _bcast_pd(
                topo, jax.tree.map(
                    lambda v: jnp.zeros_like(v, dtype=dt), params_tree),
                bundle.compute_specs, None, devices=d_virtual)
        # the staged in-flight aggregate starts as a copy of the freshly
        # replicated initial model: the step-0 prologue then commits
        # exactly w0 (bitwise), so round 0 runs from the same model the
        # oracle's round 0 does, while the first real aggregate is
        # issued at that boundary and lands one round later
        agg_next = (constrain_master(jax.tree.map(jnp.copy, params))
                    if cloud_sched.staged else None)
        delta = zeros_m(algo.delta_dtype) if needs_delta else None
        delta_next = (zeros_m(algo.delta_dtype)
                      if (algo.is_dc and algo.anchor_staleness == 1) else None)
        ef = mom = None
        if not fsdp and algo.error_feedback:
            ef = zeros_pd(jnp.float32)
        if not fsdp and algo.momentum > 0.0:
            mom = zeros_pd(jnp.float32)
        # correction slots only exist where they are read (scaffold /
        # mtgc): one per-client voter-axis buffer + one master-shaped term
        corr_cl = corr_edge = None
        if algo.has_client_correction:
            corr_cl = zeros_pd(algo.delta_dtype)
            corr_edge = zeros_m(algo.delta_dtype)
        return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                          agg_next=agg_next, delta=delta,
                          delta_next=delta_next, ef=ef, mom=mom,
                          corr_cl=corr_cl, corr_edge=corr_edge, rng=rng)

    return init_fn, train_step


def make_global_round(topo: Topology, algo: AlgoConfig, bundle: ModelBundle):
    """One fused global round: prologue + lax.scan over T_E local steps.

    Used by the dry-run/benchmarks so the compiled artifact carries the
    paper's true per-round cost (T_E one-bit local steps + one cloud sync +
    one anchor exchange) with correct 1/T_E amortization.

    batches: pytree of [T_E, P, D, b, ...].
    """
    init_fn, train_step = make_hier_step(topo, algo, bundle)

    def global_round(state: TrainState, batches, edge_weights, dev_weights,
                     dev_mask):
        def body(st, batch_t):
            st, metrics = train_step(st, {"train": batch_t}, edge_weights,
                                     dev_weights, dev_mask)
            return st, metrics["loss"]

        state, losses = jax.lax.scan(body, state, batches)
        return state, {"loss": jnp.mean(losses)}

    return init_fn, global_round


def state_shardings(topo: Topology, algo: AlgoConfig, bundle: ModelBundle,
                    abstract_state: TrainState) -> TrainState:
    """NamedSharding tree for a TrainState (dry-run / checkpoint layouts)."""
    rep = topo.sharding(jax.sharding.PartitionSpec())

    def master(tree):
        if tree is None:
            return None
        if isinstance(tree, flatbuf.FlatState):   # [P, n_pad] buffer
            spec = shardflat.buf_spec(topo, tree.layout, 1)
            return jax.tree.map(lambda _: topo.sharding(spec), tree)
        return jax.tree.map(
            lambda _, s: topo.sharding(topo.pod_spec(*s)),
            tree, bundle.master_specs)

    def dev(tree):
        if tree is None:
            return None
        if isinstance(tree, flatbuf.FlatState):   # [P, D, n_pad] buffer
            spec = shardflat.buf_spec(topo, tree.layout, 2)
            return jax.tree.map(lambda _: topo.sharding(spec), tree)
        return jax.tree.map(
            lambda _, s: topo.sharding(topo.dev_spec(*s)),
            tree, bundle.compute_specs)

    return TrainState(
        step=rep,
        params=master(abstract_state.params),
        agg_next=master(abstract_state.agg_next),
        delta=master(abstract_state.delta),
        delta_next=master(abstract_state.delta_next),
        ef=dev(abstract_state.ef),
        mom=dev(abstract_state.mom),
        corr_cl=dev(abstract_state.corr_cl),
        corr_edge=master(abstract_state.corr_edge),
        rng=rep,
    )
