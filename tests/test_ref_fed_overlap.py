"""Property suite for the ``ref_fed`` oracle's cloud sync schedule
(``HierConfig.cloud_overlap``, ``core.schedule.CloudSchedule``).

The oracle is the ground truth of the whole repo, so the overlap
semantics are pinned here *independently* of the distributed
implementation:

  * ``cloud_overlap="sync"`` is BITWISE the seed trajectory for every
    method (the schedule layer's lag=0 path is the legacy round);
  * a zero-latency commit (an explicit ``CloudSchedule(lag=0)``) routed
    through the overlap machinery collapses to the sync trajectory and
    never touches the staged slot;
  * the first overlap commit is the identity at init: round 0 runs from
    ``w0`` exactly (the staged slot lazy-initializes to the opening
    weights' sum of Q copies of ``w0``, exact on a dyadic grid);
  * each overlap round commits the aggregate issued one boundary
    earlier: ``new.w == old.w_inflight`` bitwise;
  * an all-abstaining issue round commits the identity aggregate:
    every edge leaves its model untouched, so the issued mean is
    ``sum_q ew_q * w == w`` exactly on a dyadic grid.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import ref_fed, schedule

DIM = 6
K = 2                      # clients per edge


def _grad_fn(targets):
    """Deterministic linear grads g = w - target (rng unused)."""
    def grad_fn(params, batch, rng):
        return {"w": params["w"] - targets[batch["k"]]}
    return grad_fn


def _targets(n, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, DIM)).astype(np.float32))


def _w0(seed):
    rng = np.random.default_rng(seed + 500)
    return {"w": jnp.asarray(rng.normal(size=(DIM,)).astype(np.float32))}


def _round_args(cfg, n_edges):
    batches = [[[{"k": q * K + k} for _ in range(cfg.t_e)]
                for k in range(K)] for q in range(n_edges)]
    anchors = [[{"k": q * K + k} for k in range(K)]
               for q in range(n_edges)]
    return batches, anchors


def _run(rounds, n_edges, seed, method="hier_signsgd",
         cloud_overlap="sync", t_e=3, mask_round=None):
    """Run ``rounds`` oracle rounds over ``n_edges`` edges with dyadic
    edge weights; round ``mask_round`` (if set) masks EVERY client
    out."""
    targets = _targets(n_edges * K, seed)
    cfg = ref_fed.HierConfig(mu=1e-2, t_e=t_e, rho=1.0, method=method,
                             cloud_overlap=cloud_overlap)
    state = ref_fed.init_state(_w0(seed), n_edges)
    ew = [1.0 / n_edges] * n_edges          # n_edges in {1, 2, 4}: dyadic
    batches, anchors = _round_args(cfg, n_edges)
    for t in range(rounds):
        dead = t == mask_round
        state = ref_fed.global_round(
            state, cfg, _grad_fn(targets), batches, anchors, ew,
            [[0.5, 0.5]] * n_edges, jax.random.PRNGKey(0),
            device_mask=[[not dead] * K] * n_edges,
            vote_weights=[[1] * K] * n_edges,
            reweight_participation=True)
    return state


METHODS = list(ref_fed.SIGN_METHODS) + ["hier_sgd", "hier_local_qsgd"]


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3), st.sampled_from([1, 2, 4]), st.integers(0, 4),
       st.sampled_from(METHODS))
def test_sync_mode_is_bitwise_seed_trajectory(rounds, n_edges, seed,
                                              method):
    """cloud_overlap="sync" (explicit) is bitwise the default-config
    trajectory for EVERY method, and allocates no staged slot."""
    base = _run(rounds, n_edges, seed, method)
    got = _run(rounds, n_edges, seed, method, cloud_overlap="sync")
    np.testing.assert_array_equal(np.asarray(base.w["w"]),
                                  np.asarray(got.w["w"]))
    assert got.w_inflight is None


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3), st.sampled_from([1, 2, 4]), st.integers(0, 4),
       st.sampled_from(METHODS))
def test_zero_latency_commit_collapses_to_sync(rounds, n_edges, seed,
                                               method):
    """An explicit CloudSchedule(lag=0) -- issue and commit at the SAME
    boundary -- through the overlap plumbing is bitwise the sync
    trajectory (t_e=1: every step is a boundary), and a pre-seeded
    staged slot rides through UNTOUCHED (zero latency never commits
    it)."""
    sync = _run(rounds, n_edges, seed, method, t_e=1)
    targets = _targets(n_edges * K, seed)
    cfg = ref_fed.HierConfig(mu=1e-2, t_e=1, rho=1.0, method=method,
                             cloud_overlap=schedule.CloudSchedule(lag=0))
    assert cfg.cloud_schedule().mode == "sync"
    state = ref_fed.init_state(_w0(seed), n_edges)
    junk = {"w": jnp.full((DIM,), 7.25)}
    state = dataclasses.replace(state, w_inflight=junk)
    ew = [1.0 / n_edges] * n_edges
    batches, anchors = _round_args(cfg, n_edges)
    for t in range(rounds):
        state = ref_fed.global_round(
            state, cfg, _grad_fn(targets), batches, anchors, ew,
            [[0.5, 0.5]] * n_edges, jax.random.PRNGKey(0),
            device_mask=[[True] * K] * n_edges,
            vote_weights=[[1] * K] * n_edges,
            reweight_participation=True)
    np.testing.assert_array_equal(np.asarray(sync.w["w"]),
                                  np.asarray(state.w["w"]))
    np.testing.assert_array_equal(np.asarray(state.w_inflight["w"]),
                                  np.asarray(junk["w"]))


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([1, 2, 4]), st.integers(0, 4),
       st.sampled_from(METHODS))
def test_first_overlap_commit_is_identity_at_init(n_edges, seed, method):
    """Round 0 of an overlap run commits the lazy-initialized staged
    slot -- the opening weights' sum of Q identical copies of w0, which
    is w0 EXACTLY on a dyadic grid.  So round 1 runs from w0-anchored
    models, exactly like the distributed step's staged copy(w0)."""
    state = _run(1, n_edges, seed, method, cloud_overlap="overlap")
    np.testing.assert_array_equal(np.asarray(state.w["w"]),
                                  np.asarray(_w0(seed)["w"]))
    assert state.w_inflight is not None


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3), st.sampled_from([1, 2, 4]), st.integers(0, 4),
       st.sampled_from(METHODS))
def test_overlap_commits_previous_issue(rounds, n_edges, seed, method):
    """One more round commits exactly what was in flight: the committed
    model of round r+1 IS the aggregate staged at the end of round r,
    bitwise."""
    prev = _run(rounds, n_edges, seed, method, cloud_overlap="overlap")
    nxt = _run(rounds + 1, n_edges, seed, method,
               cloud_overlap="overlap")
    np.testing.assert_array_equal(np.asarray(prev.w_inflight["w"]),
                                  np.asarray(nxt.w["w"]))


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2), st.sampled_from([1, 2, 4]), st.integers(0, 4),
       st.sampled_from(ref_fed.SIGN_METHODS))
def test_all_abstaining_issue_round_commits_identity(mask_round, n_edges,
                                                     seed, method):
    """A round in which EVERY client abstains leaves every edge model
    untouched, so the aggregate it issues is sum_q ew_q * w == w
    exactly on a dyadic grid: the staged slot after that round equals
    the round's committed model, bitwise."""
    full = _run(mask_round, n_edges, seed, method,
                cloud_overlap="overlap")
    dead = _run(mask_round + 1, n_edges, seed, method,
                cloud_overlap="overlap", mask_round=mask_round)
    # the dead round still COMMITS normally: what was in flight at its
    # opening boundary (round 0 commits the lazy init == w0 on the
    # dyadic grid)
    committed = full.w_inflight if mask_round > 0 else _w0(seed)
    np.testing.assert_array_equal(np.asarray(dead.w["w"]),
                                  np.asarray(committed["w"]))
    # ... and ISSUES the identity aggregate of its entry model
    # (full.w): no edge stepped, so sum_q ew_q * w == w exactly
    np.testing.assert_array_equal(np.asarray(dead.w_inflight["w"]),
                                  np.asarray(full.w["w"]))
