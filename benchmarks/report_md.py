"""Render the dry-run + roofline reports as the EXPERIMENTS.md tables.

    PYTHONPATH=src:. python -m benchmarks.report_md [--tag baseline]
        > reports/roofline_baseline.md
"""
from __future__ import annotations

import argparse
import sys

from benchmarks import roofline


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(tag: str):
    rows = ["| arch | shape | mesh | phase | params | bytes/dev (args) | "
            "bytes/dev (temp) | compile s |",
            "|---|---|---|---|---|---|---|---|"]
    for cell in roofline.load_cells(tag):
        if cell.get("skipped"):
            rows.append(
                f"| {cell['arch']} | {cell['shape']} | {cell['mesh']} | "
                f"SKIP | - | - | - | - |")
            continue
        for ph, r in cell["phases"].items():
            mem = r.get("memory", {})
            rows.append(
                f"| {cell['arch']} | {cell['shape']} | {cell['mesh']} | "
                f"{ph} | {cell['params']/1e9:.1f}B | "
                f"{fmt_bytes(mem.get('argument_size_in_bytes'))} | "
                f"{fmt_bytes(mem.get('temp_size_in_bytes'))} | "
                f"{r.get('compile_s', '-')} |")
    return "\n".join(rows)


def roofline_table(tag: str, t_e: int = 15):
    from repro import configs
    from repro.models.config import SHAPES
    rows = ["| arch | shape | mesh | compute s | memory s | collective s |"
            " dominant | roofline frac | useful-FLOPs ratio | "
            "data-axis B/dev | model-axis B/dev |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for cell in roofline.load_cells(tag):
        r = roofline.analyze_cell(cell, t_e)
        if r is None:
            rows.append(f"| {cell['arch']} | {cell['shape']} | "
                        f"{cell['mesh']} | - | - | - | SKIPPED | - | - | "
                        f"- | - |")
            continue
        cfg = configs.get_config(cell["arch"])
        shape = SHAPES[cell["shape"]]
        mf = roofline.model_flops(cfg, shape, cfg.active_param_count())
        hlo_global = r["compute_s"] * roofline.PEAK_FLOPS * r["chips"]
        useful = mf / hlo_global if hlo_global else 0.0
        pab = r["per_axis_bytes"]
        data_b = sum(v for k, v in pab.items() if "data" in k)
        model_b = sum(v for k, v in pab.items() if "model" in k)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['roofline_fraction']:.3f} | {useful:.3f} | "
            f"{fmt_bytes(data_b)} | {fmt_bytes(model_b)} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--section", default="both",
                    choices=["both", "dryrun", "roofline"])
    args = ap.parse_args()
    if args.section in ("both", "dryrun"):
        print("### Dry-run memory/compile table\n")
        print(dryrun_table(args.tag))
        print()
    if args.section in ("both", "roofline"):
        print("### Roofline terms (per chip, per step; train cells are "
              "T_E-amortized)\n")
        print(roofline_table(args.tag))


if __name__ == "__main__":
    main()
