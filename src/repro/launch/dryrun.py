import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. lowers the appropriate step:
       train_4k    -> hier train_step, twice: sync='never' (local 1-bit
                      step) and sync='always' (round boundary: cloud
                      aggregation + anchors) -- a global round costs
                      (T_E-1) x never + 1 x always;
       prefill_32k -> serve prefill;
       decode_*    -> serve decode_step (one token against a full cache),
  3. compiles, prints memory_analysis() + cost_analysis(),
  4. extracts per-axis collective bytes from the optimized HLO
     (benchmarks.hlo_analysis -- multiplies while-loop bodies by their
      trip counts, which compiled.cost_analysis() does NOT),
  5. appends a JSON record under reports/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm_3b \
      --shape train_4k --mesh single [--method dc_hier_signsgd]
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both
"""
import argparse
import functools
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import hier
from repro.launch import mesh as mesh_mod
from repro.launch import specs as S
from repro.models import build
from repro.models.config import SHAPES

REPORT_DIR = pathlib.Path(__file__).resolve().parents[3] / "reports" / "dryrun"


def lower_train(built, topo, algo, shape, sync):
    if sync == "never" and algo.is_overlap:
        # the overlapped schedule only changes the round prologue, which
        # sync="never" statically removes -- the local-step phase is the
        # IDENTICAL program either way, so lower it as sync (the always
        # phase keeps the overlap prologue: commit staged + issue fresh)
        import dataclasses
        algo = dataclasses.replace(algo, cloud_overlap="sync")
    _, step = hier.make_hier_step(topo, algo, built.bundle, sync=sync)
    state_abs = S.train_state_abstract(built, topo, algo)
    batch_abs = S.train_batch_abstract(built.cfg, shape, topo)
    ew, dw, mask = S.weights_abstract(topo, algo.clients)
    return jax.jit(step).lower(state_abs, batch_abs, ew, dw, mask)


def chaos_report(topo, algo, cfg, seed, steps):
    """Compile a seeded chaos schedule against this cell's membership
    and verify every emitted array matches the lowered step's abstract
    weight specs -- i.e. the whole schedule replays against ONE
    executable (churn is recompilation-free by construction)."""
    from repro.runtime import chaos, elastic
    if cfg.param_mode == "fsdp":
        return {"skipped": True,
                "reason": "client-granular membership requires the "
                          "replicated regime (FSDP lifts the voter axis "
                          "away)"}
    member = elastic.Membership(topo.pods, topo.devices_per_pod,
                                clients=algo.clients)
    inj = chaos.FaultInjector.seeded(seed, steps, topo.pods,
                                     topo.devices_per_pod,
                                     algo.clients.count)
    arrays = chaos.compile_schedule(inj, member, steps)
    specs = S.weights_abstract(topo, algo.clients)
    for arr in arrays:
        for got, want in zip(arr, specs):
            assert got.shape == want.shape and got.dtype == want.dtype, (
                f"membership array {got.shape}/{got.dtype} would retrace "
                f"a step lowered for {want.shape}/{want.dtype}")
    distinct = len({(a.edge_weights.tobytes(), a.dev_weights.tobytes(),
                     a.mask.tobytes()) for a in arrays})
    return {"skipped": False, "seed": seed, "steps": steps,
            "events": len(inj.events), "distinct_memberships": distinct,
            "recompilations": 0}


def lower_prefill(built, topo, shape):
    params_abs = S.serve_params_abstract(built, topo)
    batch_abs = S.prefill_batch_abstract(built.cfg, shape, topo)
    # VLM prompts occupy n_patches extra cache slots
    max_len = shape.seq_len + built.cfg.n_patches
    fn = functools.partial(built.prefill, max_len=max_len)
    return jax.jit(fn).lower(params_abs, batch_abs)


def lower_decode(built, topo, shape):
    params_abs = S.serve_params_abstract(built, topo)
    cache_abs, tokens_abs = S.decode_args_abstract(built, shape, topo)
    return jax.jit(built.decode_step).lower(params_abs, cache_abs,
                                            tokens_abs)


def analyze(lowered, label, verbose=True, axis_sizes=None,
            hlo_cache: pathlib.Path | None = None):
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem[k] = getattr(ma, k, None)
    except Exception as e:  # some backends lack memory analysis
        mem["error"] = str(e)
    cost = dict(compiled.cost_analysis() or {})
    if verbose:
        print(f"    [{label}] compile={compile_s:.1f}s")
        print(f"    memory_analysis: {mem}")
        print(f"    cost_analysis: flops={cost.get('flops')} "
              f"bytes={cost.get('bytes accessed')}")
    from benchmarks import hlo_analysis
    text = compiled.as_text()
    if hlo_cache is not None:
        import gzip
        hlo_cache.parent.mkdir(parents=True, exist_ok=True)
        with gzip.open(hlo_cache, "wt") as f:
            f.write(text)
    hlo = hlo_analysis.analyze_hlo_text(text, axis_sizes=axis_sizes)
    return {"label": label, "compile_s": round(compile_s, 1),
            "memory": mem,
            "xla_cost": {k: cost.get(k) for k in
                         ("flops", "bytes accessed")},
            "hlo": hlo}


def run_cell(arch_name, shape_name, multi_pod, method, transport,
             t_e, verbose=True, tag="baseline", state_layout="tree",
             clients=None, chaos_seed=None, cloud_overlap="sync"):
    shape = SHAPES[shape_name]
    cfg = configs.get_config(arch_name)
    ok, why = configs.shape_applicable(cfg, shape)
    if (ok and shape.kind == "train" and cfg.param_mode == "fsdp"
            and method in hier.CLIENT_CORRECTION_METHODS):
        # scaffold/mtgc per-client state rides the explicit voter axis,
        # which the FSDP lift never materializes -- clean SKIP instead
        # of the make_hier_step ValueError
        ok, why = False, f"{method} requires the replicated regime"
    if (ok and shape.kind == "train" and cfg.param_mode == "fsdp"
            and clients is not None and clients.active):
        ok, why = False, "virtual clients require the replicated regime"
    if (ok and shape.kind == "train" and cfg.param_mode == "fsdp"
            and cloud_overlap == "overlap"):
        # the staged in-flight aggregate is a whole-model master
        # snapshot the FSDP lift never materializes -- clean SKIP, same
        # contract as the cells above
        ok, why = False, ("cloud_overlap='overlap' requires the "
                          "replicated regime")
    cell = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "method": method, "transport": transport,
        "cloud_overlap": cloud_overlap,
        "params": None, "skipped": not ok, "skip_reason": why,
    }
    if not ok:
        print(f"  SKIP {arch_name} x {shape_name}: {why}")
        return cell
    topo = mesh_mod.make_topology(multi_pod=multi_pod)
    axis_sizes = dict(topo.mesh.shape)
    built = build.build_model(cfg, topo)
    import math
    n_params = sum(math.prod(a.shape)
                   for a in jax.tree.leaves(built.abstract_params()))
    cell["params"] = n_params
    from repro.core import clients as vclients
    algo = hier.AlgoConfig(method=method, transport=transport, t_e=t_e,
                           state_layout=state_layout,
                           cloud_overlap=cloud_overlap,
                           clients=clients or vclients.ClientConfig())
    phases = {}
    mesh_tag = "multi" if multi_pod else "single"
    hdir = REPORT_DIR / "hlo"
    hname = lambda ph: hdir / (f"{tag}.{arch_name}.{shape_name}."
                               f"{mesh_tag}.{ph}.hlo.gz")
    if shape.kind == "train":
        lowered = lower_train(built, topo, algo, shape, sync="never")
        phases["local_step"] = analyze(lowered, "local_step", verbose,
                                       axis_sizes, hname("local_step"))
        lowered = lower_train(built, topo, algo, shape, sync="always")
        phases["sync_step"] = analyze(lowered, "sync_step", verbose,
                                      axis_sizes, hname("sync_step"))
        if chaos_seed is not None:
            cell["chaos"] = chaos_report(topo, algo, cfg, chaos_seed,
                                         steps=4 * t_e)
            if verbose:
                print(f"    chaos: {cell['chaos']}")
    elif shape.kind == "prefill":
        lowered = lower_prefill(built, topo, shape)
        phases["prefill"] = analyze(lowered, "prefill", verbose, axis_sizes,
                                    hname("prefill"))
    else:
        lowered = lower_decode(built, topo, shape)
        phases["decode"] = analyze(lowered, "decode", verbose, axis_sizes,
                                   hname("decode"))
    cell["phases"] = phases
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--method", default="dc_hier_signsgd",
                    choices=hier.ALL_METHODS)
    ap.add_argument("--transport", default="ag_packed")
    ap.add_argument("--state_layout", default="tree",
                    choices=["tree", "flat"])
    ap.add_argument("--clients_per_device", type=int, default=1,
                    help="K virtual clients per data slice (per-device "
                         "batch must divide by K)")
    ap.add_argument("--client_mode", default="merged",
                    help="merged | stream (streamed in-step client loop, "
                         "O(model/32 + tally) live sign-plane memory)")
    ap.add_argument("--participation", default="full",
                    help="full | bernoulli | fixed (per-round sampled "
                         "quorum at --participation_rate)")
    ap.add_argument("--participation_rate", type=float, default=1.0)
    ap.add_argument("--alpha_client", type=float, default=None,
                    help="intra-edge Dirichlet concentration for the "
                         "synthetic stream scenario (None/inf = legacy "
                         "within-edge IID); validated up front only -- "
                         "lowering is data-independent")
    ap.add_argument("--edge_assign", default="fixed",
                    help="fixed | random | clustered client->edge "
                         "placement; clustered is rejected up front "
                         "unless the clients carve is active "
                         "(--clients_per_device>1 with --alpha_client)")
    ap.add_argument("--t_e", type=int, default=15)
    ap.add_argument("--cloud_overlap", default="sync",
                    help="sync | overlap (lagged cloud commit: the "
                         "always phase carries the staged agg_next "
                         "slot; the never phase is schedule-independent "
                         "and lowers identically; FSDP train cells "
                         "report a clean SKIP)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="attach a chaos-cell report to every train "
                         "cell: compile a seeded fault schedule "
                         "(runtime.chaos) against the cell's membership "
                         "and verify the arrays replay against the ONE "
                         "compiled step (FSDP cells report a clean "
                         "SKIP)")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    from repro.core import schedule
    if args.cloud_overlap not in schedule.CLOUD_OVERLAP_MODES:
        ap.error(f"--cloud_overlap must be one of "
                 f"{'/'.join(schedule.CLOUD_OVERLAP_MODES)}, got "
                 f"{args.cloud_overlap!r}")

    # scenario-axis validation up front: clustered assignment without an
    # active clients carve (or with a bad alpha_client) is a flag error,
    # not a deep stream-construction traceback
    from repro.data import synthetic
    try:
        synthetic.validate_scenario(synthetic.LMStreamCfg(
            vocab=2, seq_len=8,
            batch_per_device=max(args.clients_per_device, 1),
            pods=1, devices_per_pod=1,
            clients_per_device=args.clients_per_device,
            alpha_client=args.alpha_client, edge_assign=args.edge_assign))
    except ValueError as e:
        ap.error(str(e))

    archs = configs.ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    # surface the carve constraint as a clean CLI error for every
    # requested train cell, instead of a jit-time traceback
    if args.clients_per_device > 1:
        from repro.core import clients as vclients
        for multi in meshes:
            topo = mesh_mod.make_topology(multi_pod=multi)
            pd = topo.pods * topo.devices_per_pod
            for shape_name in shapes:
                shape = SHAPES[shape_name]
                if shape.kind != "train":
                    continue
                try:
                    vclients.validate_batch_carve(
                        shape.global_batch // pd, args.clients_per_device,
                        flag="clients_per_device")
                except ValueError as e:
                    ap.error(f"{shape_name} on the "
                             f"{'multi' if multi else 'single'}-pod mesh: "
                             f"{e}")

    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_tag = "multi" if multi else "single"
                out = REPORT_DIR / (f"{args.tag}.{arch}.{shape}."
                                    f"{mesh_tag}.json")
                print(f"== {arch} x {shape} x {mesh_tag} "
                      f"[{args.method}/{args.transport}] ==", flush=True)
                t0 = time.time()
                try:
                    from repro.core import clients as vclients
                    cc = vclients.ClientConfig(
                        count=args.clients_per_device,
                        participation=args.participation,
                        rate=args.participation_rate,
                        mode=args.client_mode)
                    cell = run_cell(arch, shape, multi, args.method,
                                    args.transport, args.t_e,
                                    verbose=not args.quiet, tag=args.tag,
                                    state_layout=args.state_layout,
                                    clients=cc, chaos_seed=args.chaos,
                                    cloud_overlap=args.cloud_overlap)
                    cell["wall_s"] = round(time.time() - t0, 1)
                    out.write_text(json.dumps(cell, indent=1))
                    print(f"   OK ({cell['wall_s']}s) -> {out.name}",
                          flush=True)
                except Exception:
                    n_fail += 1
                    err = traceback.format_exc()
                    out.with_suffix(".err").write_text(err)
                    print(f"   FAIL ({time.time()-t0:.0f}s):\n{err}",
                          flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
