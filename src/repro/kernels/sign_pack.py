"""Fused device-side compressor: sgn(g + rho*delta) -> 1-bit pack (TPU).

This is the hot elementwise sweep DC-HierSignSGD adds on every local step:
read the gradient (+ stale correction), take the sign, and emit the 1-bit
wire payload.  Fusing sign+pack into one VMEM pass writes d/32 uint32
words instead of a d-byte int8 sign vector -- 8x less HBM write traffic
on a pass that is bandwidth-bound by construction (DESIGN.md Sec. 6).

Tiling: the flattened parameter stream is viewed as [R, C] (C a multiple
of 32*128); each grid step processes an (BR, BC) f32 block (VMEM ~2-4 MB)
and emits a (BR, BC/32) uint32 block.  Bit j of word w holds the sign of
coordinate 32*w + j (same wire format as repro.core.signs.pack_signs).

The kernel is a single-device program: on multi-chip meshes it runs
per-rank inside the fused transport's ``shard_map`` program
(``core.votes``), where each rank packs its own model-axis bucket of
the flat buffer (``core.flatbuf`` sharded layouts) and only the packed
words travel (data-axis all-gather between this kernel and
``vote_update``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PACK = 32
BLOCK_R = 64
BLOCK_C = 4096          # 128 words per block row


def _sign_pack_kernel(g_ref, d_ref, o_ref, *, rho: float):
    g = g_ref[...].astype(jnp.float32)
    if d_ref is not None:
        g = g + rho * d_ref[...].astype(jnp.float32)
    bits = (g >= 0).astype(jnp.uint32)
    br, bc = bits.shape
    bits = bits.reshape(br, bc // PACK, PACK)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    o_ref[...] = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit,
                   static_argnames=("rho", "block_r", "block_c",
                                    "interpret", "slab_rows"))
def sign_pack(g: jax.Array, delta: jax.Array | None = None,
              rho: float = 0.0, *, block_r: int = BLOCK_R,
              block_c: int = BLOCK_C, interpret: bool = False,
              slab_rows: int | None = None) -> jax.Array:
    """g, delta: [R, C] float (R % block_r == 0, C % block_c == 0).

    slab_rows: when g stacks R/slab_rows voter slabs that all share the
    same correction (the flat-buffer transport: g rows are ordered
    (pod, device, slab_row) while delta rows are (pod, slab_row)), pass
    the per-slab row count and a delta of shape [R/replicas, C]; the
    delta block is then re-read per voter via the BlockSpec index map --
    no [P, D, n] broadcast copy of the correction ever exists in HBM.

    Returns packed uint32 [R, C/32].
    """
    r, c = g.shape
    assert r % block_r == 0 and c % block_c == 0, (g.shape, block_r, block_c)
    grid = (r // block_r, c // block_c)
    wpb = block_c // PACK

    in_specs = [pl.BlockSpec((block_r, block_c), lambda i, j: (i, j))]
    args = [g]
    if delta is not None:
        if slab_rows is None or delta.shape[0] == r:
            dmap = lambda i, j: (i, j)
        else:
            assert slab_rows % block_r == 0, (slab_rows, block_r)
            assert r % delta.shape[0] == 0, (r, delta.shape)
            rb = slab_rows // block_r          # row blocks per voter slab
            reps = r // delta.shape[0]         # voters sharing each slab
            dmap = lambda i, j: ((i // (reps * rb)) * rb + i % rb, j)
        in_specs.append(pl.BlockSpec((block_r, block_c), dmap))
        args.append(delta)
        kernel = functools.partial(_sign_pack_kernel, rho=rho)
    else:
        kernel = functools.partial(
            lambda g_ref, o_ref, *, rho: _sign_pack_kernel(
                g_ref, None, o_ref, rho=rho), rho=rho)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_r, wpb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c // PACK), jnp.uint32),
        interpret=interpret,
    )(*args)
