"""internvl2-76b [vlm]: 80L d8192 64H (kv=8) ff28672 v128256; InternViT
frontend is a STUB (precomputed patch embeddings at d_model).
[arXiv:2404.16821; unverified]
"""
import dataclasses

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, head_dim=128, rope_theta=5e5,
    n_patches=256,
    param_mode="fsdp", supports_long_context=False,
)

SMOKE = dataclasses.replace(
    CONFIG, name="internvl2-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, head_dim=16, n_patches=8,
    param_mode="replicated",
)
