"""Per-device parameter lifting: master [P, ...] -> device copies [P, D, ...].

Two regimes (DESIGN.md Sec. 5):

* ``broadcast_devices`` (replicated / gathered-ZeRO regime, small-to-mid
  archs): a plain differentiable broadcast with a sharding constraint.  The
  train step differentiates w.r.t. the *device copies*, so per-device
  gradients are ordinary JAX grads and all sign/vote/EF logic is explicit
  post-grad code (``repro.core.hier``).

* ``fsdp_lift`` (FSDP regime, 76B-671B archs): a ``custom_vjp`` whose
  forward all-gathers the layer shard into per-device copies and whose
  BACKWARD runs the paper's compression: per-device (corrected) sign ->
  1-bit vote transport over ``data`` -> scatter of the per-pod vote back
  onto the owning shard.  The "gradient" that autodiff returns for the
  master shard is therefore the majority vote s~_q (or the full-precision
  weighted mean for the HierSGD baseline / anchor passes).  This fuses
  compression into backprop -- the per-layer vote of layer i overlaps with
  the backward of layer i-1 -- and never materializes a full-model
  per-device gradient (which at 671B x 16 devices would be impossible).

The lifted copies are bitwise identical across devices; XLA keeps one copy
per data slice because of the explicit [P, D, ...] -> (pod, data, *tp)
constraint.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import signs, votes
from repro.core.topology import Topology

PyTree = Any


def _dev_shape(w: jax.Array, d: int):
    return w.shape[:1] + (d,) + w.shape[1:]


def broadcast_devices(topo: Topology, tree: PyTree, compute_specs: PyTree,
                      dtype=None, devices: int | None = None) -> PyTree:
    """[P, *leaf] master -> [P, D, *leaf] device copies (differentiable).

    compute_specs: per-leaf PartitionSpec for the *leaf* dims (TP layout).
    devices: voter-axis extent -- defaults to the mesh's physical
    ``data`` extent; virtual-client callers (``core.clients``) pass the
    merged D*K extent, which shards over ``data`` the same way (each
    physical slice holds its own K client copies).
    """
    d = devices if devices is not None else topo.devices_per_pod

    def lift(w, spec):
        wd = jnp.broadcast_to(w[:, None], _dev_shape(w, d))
        if dtype is not None and jnp.issubdtype(w.dtype, jnp.floating):
            wd = wd.astype(dtype)
        return topo.constrain(wd, P(topo.pod_axis, topo.data_axis, *spec))

    return jax.tree.map(lift, tree, compute_specs,
                        is_leaf=lambda n: n is None)


@dataclasses.dataclass(frozen=True)
class LiftCfg:
    """Static configuration for the FSDP lift (closed over, not traced)."""
    topo: Topology
    transport: str = "ag_packed"     # ag_packed | ar_int8 | wmean
                                     # ("fused" degrades to ag_packed here:
                                     # the lift votes per layer, so the
                                     # whole-tree flat buffer never forms)
    rho: float = 0.2
    compute_dtype: Any = jnp.bfloat16


def fsdp_lift(cfg: LiftCfg, w: jax.Array, delta: jax.Array,
              master_spec: P, compute_spec: P, *,
              maskf: jax.Array, devwf: jax.Array) -> jax.Array:
    """Lift one master leaf [P, *leaf] (data-sharded) to [P, D, *leaf].

    maskf:  [P, D] float voter mask (1.0 = vote counted).
    devwf:  [P, D] float device weights |D_qk|/D_q (wmean transport only).
    master_spec / compute_spec: specs for the *leaf* dims of the master
    (typically containing 'data' -> ZeRO sharding) and of the lifted copy.

    Backward: cotangent [P, D, *leaf] = true per-device gradients ->
    transport -> per-pod direction [P, *leaf], re-constrained to the master
    layout (a reduce-scatter under FSDP).
    """
    topo = cfg.topo
    d = topo.devices_per_pod
    dev_spec = P(topo.pod_axis, topo.data_axis, *compute_spec)
    pod_master_spec = P(topo.pod_axis, *master_spec)
    leaf_spec_c = P(*compute_spec)
    wdtype = w.dtype  # static (closed over; dtypes are not traced)

    @jax.custom_vjp
    def lift(w, delta, maskf, devwf):
        wd = jnp.broadcast_to(w[:, None], _dev_shape(w, d))
        return topo.constrain(wd.astype(cfg.compute_dtype), dev_spec)

    def lift_fwd(w, delta, maskf, devwf):
        return lift(w, delta, maskf, devwf), (delta, maskf, devwf)

    def lift_bwd(res, g_dev):
        delta, maskf, devwf = res
        if cfg.transport == "wmean":
            direction = votes.weighted_mean_dev(
                topo, g_dev.astype(jnp.float32), devwf)
        else:
            u = g_dev
            if cfg.rho:
                # gather the (stale) correction alongside -- pre-sign, per
                # the paper: sgn(g_qk + rho * delta_q).
                d_full = jnp.broadcast_to(
                    delta[:, None], _dev_shape(delta, d))
                d_full = topo.constrain(
                    d_full.astype(g_dev.dtype), dev_spec)
                u = g_dev + cfg.rho * d_full
            s = signs.sgn(u)
            mask = (maskf > 0.5)
            direction = votes.majority_vote_dev(
                topo, s, mask, cfg.transport, leaf_spec_c)
        direction = topo.constrain(
            direction.astype(wdtype), pod_master_spec)
        return (direction, jnp.zeros_like(delta),
                jnp.zeros_like(maskf), jnp.zeros_like(devwf))

    lift.defvjp(lift_fwd, lift_bwd)
    return lift(w, delta, maskf, devwf)


def fsdp_lift_tree(cfg: LiftCfg, tree: PyTree, delta_tree: PyTree,
                   master_specs: PyTree, compute_specs: PyTree, *,
                   maskf: jax.Array, devwf: jax.Array) -> PyTree:
    return jax.tree.map(
        lambda w, dl, ms, cs: fsdp_lift(cfg, w, dl, ms, cs,
                                        maskf=maskf, devwf=devwf),
        tree, delta_tree, master_specs, compute_specs,
        is_leaf=lambda n: n is None)
