"""Architecture configuration dataclasses (one instance per assigned arch).

All fields mirror the public configs cited in the assignment; reduced
`smoke` variants shrink width/depth/vocab but keep the family's structure
(MoE stays MoE, hybrid stays hybrid) so smoke tests exercise the same code
paths as the full configs.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0            # shared (always-on) experts, deepseek-style
    dense_residual_ff: int = 0   # arctic-style parallel dense MLP width
    first_dense: int = 0         # leading dense layers (deepseek-v3: 3)
    dense_ff: int = 0            # ff width of those dense layers
    capacity_factor: float = 1.25
    group_tokens: int = 1024     # GShard dispatch group size
    aux_loss_coef: float = 0.01
    dispatch: str = "einsum"     # einsum (GShard baseline) | gather (opt)


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    n_groups: int = 1
    chunk: int = 256
    attn_every: int = 6          # zamba2: shared attn block cadence


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    m_per_s: int = 7             # mLSTM blocks per sLSTM block
    proj_factor: float = 2.0     # mLSTM up-projection
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # attention flavour
    window: int = 0              # sliding window size (local layers)
    local_global: tuple[int, int] | None = None  # e.g. (5, 1) for gemma3
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_theta_global: float = 1e6
    tie_embed: bool = False
    embed_scale: bool = False    # gemma: x *= sqrt(d_model)
    # family extensions
    moe: MoECfg | None = None
    mla: MLACfg | None = None
    ssm: SSMCfg | None = None
    xlstm: XLSTMCfg | None = None
    mtp: bool = False            # deepseek multi-token prediction head
    mtp_loss_weight: float = 0.3
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 1500   # stub-encoded audio frame count
    frontend_dim: int = 0        # stub frontend input feature dim
    # vlm
    n_patches: int = 0           # stub patch-embedding count (internvl)
    # norm / act
    act: str = "swiglu"          # swiglu | gelu
    norm_eps: float = 1e-6
    # distribution hints
    param_mode: str = "replicated"   # replicated | fsdp
    supports_long_context: bool = False
    remat: bool = True
    # which serve shapes apply (encoder-only archs would drop decode)
    has_decoder: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Approximate parameter count (sanity checks / roofline 6ND)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embed else 2)
        per_layer = 0
        # attention
        if self.mla is not None:
            m = self.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_layer += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.n_heads * (
                m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * d
        elif self.family not in ("ssm", "hybrid"):
            per_layer += d * self.hd * (self.n_heads + 2 * self.n_kv_heads)
            per_layer += self.n_heads * self.hd * d
        # ffn / experts
        if self.moe is not None:
            e = self.moe
            expert = 3 * d * e.d_expert
            per_layer += e.n_experts * expert + e.n_shared * expert
            per_layer += d * e.n_experts                     # router
            if e.dense_residual_ff:
                per_layer += 3 * d * e.dense_residual_ff
        elif self.d_ff:
            mult = 3 if self.act == "swiglu" else 2
            per_layer += mult * d * self.d_ff
        n_moe = L - (self.moe.first_dense if self.moe else 0)
        total = emb + per_layer * (n_moe if self.moe else L)
        if self.moe and self.moe.first_dense:
            mult = 3 if self.act == "swiglu" else 2
            dense_l = (d * self.hd * (self.n_heads + 2 * self.n_kv_heads)
                       + self.n_heads * self.hd * d
                       + mult * d * self.moe.dense_ff)
            if self.mla is not None:
                m = self.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                dense_l = (d * m.q_lora_rank
                           + m.q_lora_rank * self.n_heads * qk
                           + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                           + m.kv_lora_rank * self.n_heads
                           * (m.qk_nope_head_dim + m.v_head_dim)
                           + self.n_heads * m.v_head_dim * d
                           + mult * d * self.moe.dense_ff)
            total += self.moe.first_dense * dense_l
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k), for MODEL_FLOPS = 6*N_act*D."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        full = self.param_count()
        expert = 3 * self.d_model * e.d_expert
        n_moe = self.n_layers - e.first_dense
        inactive = n_moe * (e.n_experts - e.top_k) * expert
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """One assigned input-shape cell."""
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCfg("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524288, 1),
}
