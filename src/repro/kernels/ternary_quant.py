"""Fused stochastic ternary quantizer (Hier-Local-QSGD baseline compressor).

Q(x)_i = ||x||_2 * sign(x_i) with prob |x_i| / ||x||_2, else 0 (unbiased).
The global l2 norm is a cheap pre-pass reduction done outside; the kernel
fuses probability computation, Bernoulli draw (from supplied uniforms) and
ternarization into one VMEM sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 64
BLOCK_C = 4096


def _ternary_kernel(x_ref, u_ref, n_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    norm = n_ref[0]
    p = jnp.abs(x) / jnp.maximum(norm, 1e-30)
    q = jnp.where(u < p, norm * jnp.sign(x), 0.0)
    o_ref[...] = jnp.where(norm > 0, q, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_r", "block_c", "interpret"))
def ternary_quant(x: jax.Array, u: jax.Array, norm: jax.Array, *,
                  block_r: int = BLOCK_R, block_c: int = BLOCK_C,
                  interpret: bool = False) -> jax.Array:
    """x, u: [R, C]; norm: scalar ||x||_2 (precomputed)."""
    r, c = x.shape
    assert r % block_r == 0 and c % block_c == 0
    grid = (r // block_r, c // block_c)
    return pl.pallas_call(
        _ternary_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, u, norm.reshape(1))
