"""Failure detection + recovery policy for the training driver.

Detection signals:
  * non-finite loss (desync / data corruption / numeric blow-up),
  * step-time outliers (straggler escalation: after ``patience``
    consecutive slow steps a device is demoted to abstention via the
    vote mask; the paper's majority vote makes this loss-free),
  * injected faults (tests / chaos engineering hooks).

Recovery: restore the newest intact checkpoint and replay.  Because the
data pipeline is cursor-addressable (batch = f(seed, step)), replay is
deterministic.
"""
from __future__ import annotations

import dataclasses
import math
import time


@dataclasses.dataclass
class FailurePolicy:
    straggler_factor: float = 3.0    # x median step time
    patience: int = 3
    max_restores: int = 5


class FailureDetector:
    def __init__(self, policy: FailurePolicy | None = None):
        self.policy = policy or FailurePolicy()
        self.step_times: list[float] = []
        self.slow_counts: dict[tuple[int, int], int] = {}
        self.restores = 0

    def check_loss(self, loss: float) -> bool:
        """True -> healthy; False -> restore required."""
        return math.isfinite(loss)

    def record_step(self, dt: float):
        self.step_times.append(dt)
        if len(self.step_times) > 256:
            self.step_times.pop(0)

    def median_step(self) -> float:
        if not self.step_times:
            return 0.0
        s = sorted(self.step_times)
        return s[len(s) // 2]

    def device_slow(self, pod: int, dev: int, dt: float) -> bool:
        """Per-device straggler accounting; True -> demote to abstention."""
        med = self.median_step()
        key = (pod, dev)
        if med and dt > self.policy.straggler_factor * med:
            self.slow_counts[key] = self.slow_counts.get(key, 0) + 1
        else:
            self.slow_counts[key] = 0
        return self.slow_counts[key] >= self.policy.patience

    def may_restore(self) -> bool:
        self.restores += 1
        return self.restores <= self.policy.max_restores


class FaultInjector:
    """Deterministic chaos hooks for tests/examples."""

    def __init__(self, schedule: dict[int, tuple[str, int, int | None]]):
        # schedule: step -> ("device"|"pod"|"nan", pod, dev)
        self.schedule = schedule

    def at(self, step: int):
        return self.schedule.get(step)
