"""Distributed majority-vote transports over the ``data`` (device) axis.

Input: per-device quantities laid out ``[P, D, *leaf]`` (P pods = edges,
D data slices = devices).  Output: per-pod vote ``[P, *leaf]``.

Two wire formats (DESIGN.md Sec. 2 "Vote transport"):

``ag_packed``  (paper-faithful) -- each device contributes a bit-packed sign
    row (1 bit/coordinate, exactly the paper's uplink payload); the packed
    rows are all-gathered along ``data`` and every chip computes the same
    popcount vote -- this *is* the paper's "edge broadcasts the vote back",
    with zero additional downlink.  Leaves whose minor dim is not a multiple
    of 32 fall back to ``ar_int8`` (negligible bytes; documented).

``ar_int8``  (beyond-paper optimized) -- the vote sgn(sum_k sgn g_k) is
    computed distributively via an int8 all-reduce of the sign tally
    (|sum| <= D <= 127 fits int8).  8 bits/coordinate on the wire but a
    single reduction phase, and under FSDP the tally reduce-scatters
    straight onto the owning shard.  Bit-identical votes (tested).

``mean`` / ``wmean`` -- full-precision weighted averaging (HierSGD baseline).

All functions are pure jnp + sharding constraints: they lower to data-axis
collectives under GSPMD and degenerate to local arithmetic at P=D=1 (which
is how they are unit-tested against ``repro.core.signs``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import signs
from repro.core.topology import Topology

PACK = signs.PACK_WIDTH


def _mask_bcast(mask: jax.Array | None, ndim_leaf: int):
    """[P, D] voter mask -> broadcastable to [P, D, *leaf]."""
    if mask is None:
        return None
    return mask.reshape(mask.shape + (1,) * ndim_leaf)


def vote_ar_int8(topo: Topology, s_dev: jax.Array,
                 mask: jax.Array | None) -> jax.Array:
    """sgn(sum_k s_k) via an int8 tally reduction over the device axis."""
    tally = s_dev.astype(jnp.int8)
    m = _mask_bcast(mask, s_dev.ndim - 2)
    n_eff = None
    if m is not None:
        tally = tally * m.astype(jnp.int8)
        n_eff = jnp.sum(mask.astype(jnp.int32), axis=1)        # [P]
        n_eff = n_eff.reshape((-1,) + (1,) * (s_dev.ndim - 2))
    tally = jnp.sum(tally, axis=1, dtype=jnp.int8)             # [P, *leaf]
    if n_eff is None:
        return signs.sgn(tally.astype(jnp.int32))
    # with abstentions the tie rule is 2*pos >= n_eff  <=>  tally >= 0
    return signs.sgn(tally.astype(jnp.int32))


def vote_ag_packed(topo: Topology, s_dev: jax.Array,
                   mask: jax.Array | None, leaf_spec: P) -> jax.Array:
    """Bit-packed all-gather + local popcount vote (1 bit/coord wire).

    s_dev: [P, D, *leaf] int8 signs; leaf minor dim % 32 == 0 required.
    The packed words are constrained to be replicated along ``data`` --
    that resharding is the all-gather whose operand is 1/32 the int8 tally
    (and 1/256 the fp32 gradient) -- then every chip votes locally.
    """
    *lead, minor = s_dev.shape
    assert minor % PACK == 0, "caller guarantees minor % 32 == 0"
    words = signs.pack_signs(s_dev)                            # [P, D, *l, minor/32]
    # device-axis all-gather of the 1-bit payload: keep every other dim's
    # sharding, drop 'data' from dim 1.
    gathered_spec = P(topo.pod_axis, None, *leaf_spec)
    words = topo.constrain(words, gathered_spec)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)        # [P,D,*l,w,32]
    bits = bits.astype(jnp.int8)
    if mask is not None:
        m = _mask_bcast(mask, bits.ndim - 2)
        pos = jnp.sum(bits * m.astype(jnp.int8), axis=1, dtype=jnp.int32)
        n_eff = jnp.sum(mask.astype(jnp.int32), axis=1)
        n_eff = n_eff.reshape((-1,) + (1,) * (pos.ndim - 1))
    else:
        pos = jnp.sum(bits, axis=1, dtype=jnp.int32)           # [P,*l,w,32]
        n_eff = s_dev.shape[1]
    vote = jnp.where(2 * pos >= n_eff, jnp.int8(1), jnp.int8(-1))
    return vote.reshape(s_dev.shape[:1] + s_dev.shape[2:])     # [P, *leaf]


def majority_vote_dev(topo: Topology, s_dev: jax.Array,
                      mask: jax.Array | None, transport: str,
                      leaf_spec: P) -> jax.Array:
    """Vote [P, D, *leaf] -> [P, *leaf]; dispatch on transport + leaf shape."""
    if transport == "ag_packed" and s_dev.shape[-1] % PACK == 0:
        return vote_ag_packed(topo, s_dev, mask, leaf_spec)
    return vote_ar_int8(topo, s_dev, mask)


def weighted_mean_dev(topo: Topology, g_dev: jax.Array,
                      dev_weights: jax.Array) -> jax.Array:
    """Full-precision edge aggregation  sum_k (|D_qk|/D_q) g_k  -> [P, *leaf]."""
    w = dev_weights.reshape(dev_weights.shape + (1,) * (g_dev.ndim - 2))
    return jnp.sum(g_dev * w.astype(g_dev.dtype), axis=1)


# ---------------------------------------------------------------------------
# Pod (edge -> cloud) tier
# ---------------------------------------------------------------------------

def pod_weighted_average(topo: Topology, v: jax.Array,
                         edge_weights: jax.Array) -> jax.Array:
    """Cloud aggregation  w = sum_q (D_q/N) v_q, broadcast back to [P, ...].

    v: [P, *leaf].  Lowers to a pod-axis all-reduce (the edge->cloud model
    exchange, every T_E steps).
    """
    w = edge_weights.reshape((-1,) + (1,) * (v.ndim - 1)).astype(v.dtype)
    glob = jnp.sum(v * w, axis=0, keepdims=True)               # [1, *leaf]
    return jnp.broadcast_to(glob, v.shape)
