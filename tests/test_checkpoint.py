"""Checkpoint store: roundtrip, atomicity, corruption fallback, GC, async,
and flat-state (FlatLayout metadata) save/restore with tree<->flat
conversion both ways."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.checkpoint.async_ckpt import AsyncSaver
from repro.core import flatbuf


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 8)),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "step": jnp.asarray(seed, jnp.int32),
        "rng": jax.random.PRNGKey(seed + 1),
        "none_leaf": None,
    }


def test_roundtrip(tmp_path):
    t = _tree(3)
    store.save(tmp_path, 3, t)
    out = store.restore(tmp_path, 3, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bf16_and_key_roundtrip(tmp_path):
    t = _tree(1)
    store.save(tmp_path, 1, t)
    out = store.restore(tmp_path, 1, t)
    assert out["params"]["b"].dtype == jnp.bfloat16
    # keys usable after restore
    jax.random.normal(out["rng"], (2,))


def test_latest_and_gc(tmp_path):
    t = _tree(0)
    for s in [1, 2, 3, 4, 5]:
        store.save(tmp_path, s, t, keep=2)
    assert store.available_steps(tmp_path) == [4, 5]
    assert (tmp_path / "LATEST").read_text() == "5"


def test_corruption_falls_back(tmp_path):
    t = _tree(0)
    store.save(tmp_path, 1, t, keep=5)
    store.save(tmp_path, 2, t, keep=5)
    # corrupt the newest
    npz = tmp_path / "step_0000000002" / "arrays.npz"
    npz.write_bytes(b"garbage")
    got = store.restore_latest(tmp_path, t)
    assert got is not None and got[0] == 1


def test_restore_latest_none_when_empty(tmp_path):
    assert store.restore_latest(tmp_path / "nope", _tree()) is None


def test_async_saver(tmp_path):
    saver = AsyncSaver(tmp_path, keep=2)
    for s in [10, 20]:
        saver.submit(s, _tree(s))
    saver.close()
    assert store.available_steps(tmp_path) == [10, 20]
    out = store.restore(tmp_path, 20, _tree(20))
    assert int(out["step"]) == 20


def test_async_saver_surfaces_worker_failure(tmp_path, monkeypatch):
    """A failed background save is re-raised on the next wait() with
    the original error chained -- and the writer thread SURVIVES the
    failure, so later saves still land."""
    from repro.checkpoint import async_ckpt
    orig = store.save

    def flaky(ckpt_dir, step, tree, keep=3):
        if step == 1:
            raise IOError("disk full")
        return orig(ckpt_dir, step, tree, keep=keep)

    monkeypatch.setattr(async_ckpt.store, "save", flaky)
    saver = AsyncSaver(tmp_path, keep=5)
    saver.submit(1, _tree(1))
    with pytest.raises(RuntimeError,
                       match="background checkpoint save failed") as exc:
        saver.wait()
    assert isinstance(exc.value.__cause__, IOError)
    saver.submit(2, _tree(2))
    saver.close()
    assert store.available_steps(tmp_path) == [2]


def test_async_saver_submit_reraises(tmp_path, monkeypatch):
    """submit() surfaces a pending background failure too (a training
    loop that never calls wait() until the end still finds out at the
    next checkpoint interval)."""
    import time

    from repro.checkpoint import async_ckpt

    def failing(*a, **kw):
        raise IOError("disk full")

    monkeypatch.setattr(async_ckpt.store, "save", failing)
    saver = AsyncSaver(tmp_path)
    saver.submit(1, _tree(1))
    deadline = time.time() + 10
    while saver._err is None and time.time() < deadline:
        time.sleep(0.01)
    with pytest.raises(RuntimeError,
                       match="background checkpoint save failed"):
        saver.submit(2, _tree(2))
    saver.close()


def test_async_saver_malformed_item_cannot_deadlock(tmp_path):
    """Regression: an item the worker cannot even unpack used to kill
    the thread OUTSIDE the task_done() guard, deadlocking wait()
    forever; now it surfaces like any other failure and the worker
    keeps serving."""
    saver = AsyncSaver(tmp_path)
    saver._q.put("bogus")        # simulate a corrupted handoff
    with pytest.raises(RuntimeError,
                       match="background checkpoint save failed"):
        saver.wait()
    saver.submit(3, _tree(3))
    saver.close()
    assert store.available_steps(tmp_path) == [3]


def test_async_saver_submit_after_close_raises(tmp_path):
    """Steps submitted to a closed saver would never reach disk --
    refuse loudly instead of enqueueing into the void."""
    saver = AsyncSaver(tmp_path)
    saver.close()
    with pytest.raises(RuntimeError, match="not running"):
        saver.submit(1, _tree(1))


def test_manifest_records_leaves(tmp_path):
    t = _tree(0)
    path = store.save(tmp_path, 7, t)
    manifest = json.loads((path / "manifest.json").read_text())
    assert manifest["step"] == 7
    assert any("params/w" in k for k in manifest["leaves"])


def _train_state(staged: bool):
    """A minimal hier.TrainState, with or without the overlap schedule's
    staged in-flight aggregate."""
    from repro.core import hier
    p = {"w": jnp.arange(8.0).reshape(2, 4), "b": jnp.ones((3,))}
    agg = jax.tree.map(lambda x: x + 1.0, p) if staged else None
    return hier.TrainState(step=jnp.asarray(4, jnp.int32), params=p,
                           agg_next=agg, delta=None, delta_next=None,
                           ef=None, mom=None, corr_cl=None,
                           corr_edge=None, rng=jax.random.PRNGKey(0))


def test_overlap_staged_slot_roundtrip(tmp_path):
    """The staged in-flight aggregate (TrainState.agg_next,
    cloud_overlap="overlap") is recorded in the manifest and restored
    bit-exactly -- mid-flight kill-restore-replay depends on it.  A
    pre-overlap (sync) checkpoint restored into an overlap state
    template fails loudly instead of fabricating an in-flight
    aggregate."""
    t = _train_state(staged=True)
    path = store.save(tmp_path / "a", 4, t)
    manifest = json.loads((path / "manifest.json").read_text())
    assert any("agg_next" in k for k in manifest["leaves"])
    out = store.restore(tmp_path / "a", 4, t)
    for k in t.params:
        np.testing.assert_array_equal(np.asarray(out.agg_next[k]),
                                      np.asarray(t.agg_next[k]))
    store.save(tmp_path / "b", 5, _train_state(staged=False))
    with pytest.raises(IOError, match="missing leaf"):
        store.restore(tmp_path / "b", 5, t)


# ---------------------------------------------------------------------------
# Flat state (state_layout="flat")
# ---------------------------------------------------------------------------

def _flat_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    leaves = {"w": jax.random.normal(k, (2, 4, 8)),
              "b": jax.random.normal(jax.random.fold_in(k, 1), (2, 33),
                                     jnp.bfloat16)}
    fs = flatbuf.from_tree(leaves, batch_dims=1)
    # fused-update padding drift: padding coords are don't-care and must
    # not leak into (or be required by) the tree form
    fs = fs.replace(fs.buf.at[..., fs.layout.n:].set(-7.0))
    return {"params": fs, "step": jnp.asarray(seed, jnp.int32),
            "rng": jax.random.PRNGKey(seed + 1)}


def test_flat_roundtrip_records_layout(tmp_path):
    t = _flat_tree(3)
    path = store.save(tmp_path, 3, t)
    manifest = json.loads((path / "manifest.json").read_text())
    meta = manifest["flat_state"]["params"]
    lay = t["params"].layout
    assert meta["n"] == lay.n and meta["n_pad"] == lay.n_pad
    assert [s["offset"] for s in meta["slots"]] == [
        s.offset for s in lay.slots]
    out = store.restore(tmp_path, 3, t)
    np.testing.assert_array_equal(np.asarray(out["params"].buf),
                                  np.asarray(t["params"].buf))


def test_flat_tree_conversion_roundtrip(tmp_path):
    """save flat -> load tree -> save tree -> load flat: bit-exact."""
    t = _flat_tree(5)
    tree_like = dict(t, params=t["params"].tree())
    store.save(tmp_path / "a", 1, t)
    as_tree = store.restore(tmp_path / "a", 1, tree_like)
    for a, b in zip(jax.tree.leaves(as_tree["params"]),
                    jax.tree.leaves(tree_like["params"])):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    store.save(tmp_path / "b", 2, as_tree)
    as_flat = store.restore(tmp_path / "b", 2, t)
    for a, b in zip(jax.tree.leaves(as_flat["params"].tree()),
                    jax.tree.leaves(t["params"].tree())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _sharded_flat_tree(seed=0, shards=2):
    from jax.sharding import PartitionSpec as P
    k = jax.random.PRNGKey(seed)
    leaves = {"w": jax.random.normal(k, (2, 4, 8)),
              "b": jax.random.normal(jax.random.fold_in(k, 1), (2, 33),
                                     jnp.bfloat16)}
    specs = {"w": P(None, "model"), "b": P(None)}
    fs = flatbuf.from_tree(
        leaves, batch_dims=1,
        sharding=flatbuf.ModelSharding(shards, "model", specs))
    assert fs.layout.shards == shards
    return {"params": fs, "step": jnp.asarray(seed, jnp.int32)}, leaves


def test_sharded_flat_conversions(tmp_path):
    """Model-axis-sharded layouts round-trip flat<->flat and convert to
    and from tree checkpoints bit-exactly (blocks reassembled along
    shard_dim, per-bucket copies collapsed); restoring a sharded flat
    checkpoint into a DIFFERENTLY-sharded flat run goes through the
    tree form transparently (logical leaves agree)."""
    t, leaves = _sharded_flat_tree(7)
    path = store.save(tmp_path / "a", 1, t)
    meta = json.loads((path / "manifest.json").read_text())
    assert meta["flat_state"]["params"]["shards"] == 2
    out = store.restore(tmp_path / "a", 1, t)          # flat -> flat
    np.testing.assert_array_equal(np.asarray(out["params"].buf),
                                  np.asarray(t["params"].buf))
    as_tree = store.restore(tmp_path / "a", 1,          # flat -> tree
                            dict(t, params=leaves))
    for k in leaves:
        assert as_tree["params"][k].dtype == leaves[k].dtype
        np.testing.assert_array_equal(np.asarray(as_tree["params"][k]),
                                      np.asarray(leaves[k]))
    store.save(tmp_path / "b", 2, dict(t, params=leaves))
    as_flat = store.restore(tmp_path / "b", 2, t)       # tree -> flat
    np.testing.assert_array_equal(np.asarray(as_flat["params"].buf),
                                  np.asarray(t["params"].buf))
    # sharded ckpt -> UNSHARDED flat run: tree-form conversion, exact
    unsharded = flatbuf.from_tree(leaves, batch_dims=1)
    re_un = store.restore(tmp_path / "a", 1, dict(t, params=unsharded))
    for a, b in zip(jax.tree.leaves(re_un["params"].tree()),
                    jax.tree.leaves(leaves)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _uneven_sharded_flat_tree(seed=0, shards=2):
    """FlatState with an UNEVEN model-sharded leaf (33 % 2 != 0): the
    padded-shard layout stores it as zero-tailed blocks."""
    from jax.sharding import PartitionSpec as P
    k = jax.random.PRNGKey(seed)
    leaves = {"w": jax.random.normal(k, (2, 4, 8)),
              "b": jax.random.normal(jax.random.fold_in(k, 1), (2, 33))}
    specs = {"w": P(None, "model"), "b": P("model")}
    fs = flatbuf.from_tree(
        leaves, batch_dims=1,
        sharding=flatbuf.ModelSharding(shards, "model", specs))
    assert fs.layout.shards == shards
    b_slot = fs.layout.slots[0]              # canonical order: b first
    assert (b_slot.shard_dim, b_slot.shard_pad) == (0, 1)
    return {"params": fs, "step": jnp.asarray(seed, jnp.int32)}, leaves


def test_uneven_sharded_flat_roundtrip(tmp_path):
    """An uneven sharded FlatState round-trips flat<->flat bit-exactly,
    converts to and from tree checkpoints exactly (the shard zero tail
    never transfers), and the manifest records the LOGICAL global
    shape."""
    t, leaves = _uneven_sharded_flat_tree(3)
    path = store.save(tmp_path / "a", 1, t)
    meta = json.loads((path / "manifest.json").read_text())
    slot_b = meta["flat_state"]["params"]["slots"][0]
    assert slot_b["key"] == "b"
    assert slot_b["global_shape"] == [33]    # logical, not padded 34
    assert slot_b["shard_pad"] == 1
    out = store.restore(tmp_path / "a", 1, t)           # flat -> flat
    np.testing.assert_array_equal(np.asarray(out["params"].buf),
                                  np.asarray(t["params"].buf))
    as_tree = store.restore(tmp_path / "a", 1,          # flat -> tree
                            dict(t, params=leaves))
    for k in leaves:
        np.testing.assert_array_equal(np.asarray(as_tree["params"][k]),
                                      np.asarray(leaves[k]))
    store.save(tmp_path / "b", 2, dict(t, params=leaves))
    as_flat = store.restore(tmp_path / "b", 2, t)       # tree -> flat
    np.testing.assert_array_equal(np.asarray(as_flat["params"].buf),
                                  np.asarray(t["params"].buf))


def test_uneven_restore_from_old_copy_manifest(tmp_path):
    """A checkpoint written by the OLD layout rule (uneven leaf stored
    as a per-bucket COPY, manifest without global_shape/shard_pad)
    still restores into the padded-shard layout via tree conversion."""
    from jax.sharding import PartitionSpec as P
    t, leaves = _uneven_sharded_flat_tree(5)
    # rebuild the old copy-style layout by hand: w sharded (8 % 2 == 0),
    # b replicated -> copied whole into both buckets (what the old rule
    # did to the uneven leaf)
    copy_style = flatbuf.make_layout(
        leaves, batch_dims=1, sharding=flatbuf.ModelSharding(
            2, "model", {"w": P(None, "model"), "b": P()}))
    assert copy_style.shards == 2
    assert [s.shard_dim for s in copy_style.slots] == [None, 1]
    buckets = [flatbuf.flatten_tree(copy_style.bucket(), bt, batch_dims=1)
               for bt in flatbuf.bucket_trees(copy_style, leaves, 1)]
    legacy_fs = flatbuf.FlatState(jnp.concatenate(buckets, axis=-1),
                                  copy_style, batch_dims=1)
    path = store.save(tmp_path, 1, dict(t, params=legacy_fs))
    # age the manifest: strip the fields old checkpoints did not have
    manifest = json.loads((path / "manifest.json").read_text())
    for slot in manifest["flat_state"]["params"]["slots"]:
        slot.pop("global_shape")
        slot.pop("shard_pad")
    (path / "manifest.json").write_text(json.dumps(manifest, indent=1))
    restored = store.restore(tmp_path, 1, t)   # old copy -> padded shard
    np.testing.assert_array_equal(np.asarray(restored["params"].buf),
                                  np.asarray(t["params"].buf))
    for a, b in zip(jax.tree.leaves(restored["params"].tree()),
                    jax.tree.leaves(leaves)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_layout_mismatch_error_names_leaf_and_field(tmp_path):
    """A genuinely incompatible flat layout raises naming the offending
    leaf path and field, not a whole-slot-table dump."""
    t, leaves = _uneven_sharded_flat_tree(0)
    store.save(tmp_path, 1, t)
    other = flatbuf.from_tree(
        {"w": leaves["w"], "b": jnp.zeros((2, 34))}, batch_dims=1)
    with pytest.raises(IOError, match=r"leaf 'params/b'.*expects \(34,\)"):
        store.restore(tmp_path, 1, dict(t, params=other))


def test_flat_restore_validates_layout(tmp_path):
    t = _flat_tree(0)
    store.save(tmp_path, 1, t)
    other = flatbuf.from_tree(
        {"w": jnp.zeros((2, 5, 5)), "b": jnp.zeros((2, 33))}, batch_dims=1)
    with pytest.raises(IOError, match="layout mismatch"):
        store.restore(tmp_path, 1, dict(t, params=other))
    # identical slot table, wrong batch shape (e.g. devices-per-pod
    # changed between save and restore) must raise too
    lay = t["params"].layout
    wrong_batch = flatbuf.FlatState(jnp.zeros((3, lay.n_pad), lay.dtype),
                                    lay)
    with pytest.raises(IOError, match="layout mismatch"):
        store.restore(tmp_path, 1, dict(t, params=wrong_batch))
    missing = {"params": t["params"], "step": t["step"],
               "rng": t["rng"], "extra": jnp.zeros((2,))}
    with pytest.raises(IOError, match="missing leaf"):
        store.restore(tmp_path, 1, missing)


def test_flat_conversion_matches_by_key_not_position(tmp_path):
    """A renamed leaf of identical shape must raise, never be silently
    loaded into another slot's coordinates."""
    t = _flat_tree(0)
    # tree checkpoint -> flat run with a renamed leaf (same shapes)
    tree_like = dict(t, params=t["params"].tree())
    store.save(tmp_path / "a", 1, tree_like)
    renamed = flatbuf.from_tree(
        {"v": tree_like["params"]["w"], "b": tree_like["params"]["b"]},
        batch_dims=1)
    with pytest.raises(IOError, match="missing leaf"):
        store.restore(tmp_path / "a", 1, dict(t, params=renamed))
    # flat checkpoint -> flat run with a renamed leaf: slot-table keys
    # differ -> layout mismatch
    store.save(tmp_path / "b", 2, t)
    with pytest.raises(IOError, match="layout mismatch"):
        store.restore(tmp_path / "b", 2, dict(t, params=renamed))
    # flat checkpoint -> tree run with a renamed leaf -> missing leaf
    tree_renamed = dict(t, params={"v": tree_like["params"]["w"],
                                   "b": tree_like["params"]["b"]})
    with pytest.raises(IOError, match="missing leaf"):
        store.restore(tmp_path / "b", 2, tree_renamed)
