"""Shared parity fixture: ONE toy problem + runners for every supported
(method x transport x state_layout x regime) train-step combination and
for the ``ref_fed`` paper oracle on the SAME trajectory.

Used two ways:
  * in-process by ``tests/test_parity_matrix.py`` on the default
    single-device runtime (P = D = 1);
  * by ``tests/helpers/parity_matrix_check.py`` in a subprocess with 8
    forced host devices (P = D = 2, TP = 2), which replaces the old
    ad-hoc ``fused_parity_check.py`` / ``multidev_oracle_check.py``
    scratch scripts.

The toy model is a deterministic 2-matrix linear regression with an
odd-minor bias (33 % 32 != 0 exercises the packed-transport fallbacks)
and per-pod heterogeneous targets (so the DC correction has something
to correct).  All runners consume identical batches, seeds and masks;
sign transports and state layouts must agree BITWISE, the oracle and
the FSDP regime within float tolerance.

``make_problem(..., hid=...)`` widens the matrix: an ODD hidden dim
(``UNEVEN_HID``) makes both weight matrices model-shard unevenly under
the canonical Megatron specs (w column-parallel, w2 row-parallel), so
the sharded flat layout must engage its padded-shard blocks
(``LeafSlot.shard_pad``) -- the uneven-TP-leaf parity cell of
``sharded_fused_check.py`` / ``parity_matrix_check.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.checkpoint import store
from repro.core import clients as vclients
from repro.core import hier, ref_fed
from repro.core.topology import Topology
from repro.data import cluster
from repro.runtime import chaos, elastic

DIN, HID, DOUT = 16, 64, 33
UNEVEN_HID = 65       # odd: w/w2 model-shard unevenly (padded blocks)


def loss_fn(params, batch, rng):
    h = batch["x"] @ params["w"]
    pred = h @ params["w2"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


COMPUTE_SPECS = {"w": P(None, "model"), "b": P(None),
                 "w2": P("model", None)}
FSDP_MASTER_SPECS = {"w": P("data", "model"), "b": P(None),
                     "w2": P("model", None)}


def make_problem(pods: int, devs: int, rounds: int = 3, t_e: int = 3,
                 batch: int = 8, seed: int = 0, hid: int = HID,
                 clients: int = 1, alpha_client: float | None = None):
    """Deterministic batches [S, P, D, B, .] with per-pod targets.

    ``alpha_client`` adds INTRA-edge heterogeneity on top: each of the
    ``clients`` virtual clients per slice regresses on its own target --
    a Dirichlet(alpha_client) mixture of the pod prototype targets,
    centered on the client's own pod -- and its rows of the slice batch
    (``[c*b/K, (c+1)*b/K)``, the carve contract) are generated from that
    target.  ``alpha_client=None`` (default) is the exact legacy
    per-pod-target problem."""
    key = jax.random.PRNGKey(seed)
    w0 = {"w": jax.random.normal(key, (DIN, hid)) * 0.3,
          "b": jnp.zeros((DOUT,)),
          "w2": jax.random.normal(jax.random.fold_in(key, 1),
                                  (hid, DOUT)) * 0.3}
    steps = rounds * t_e
    xs = jax.random.normal(jax.random.PRNGKey(seed + 7),
                           (steps, pods, devs, batch, DIN))
    w_true = jax.random.normal(jax.random.PRNGKey(seed + 9),
                               (pods, DIN, DOUT))
    if alpha_client is None:
        ys = jnp.einsum("spdbi,pio->spdbo", xs, w_true)
    else:
        assert batch % clients == 0, (batch, clients)
        protos = np.asarray(jax.random.normal(
            jax.random.PRNGKey(seed + 21), (4, DIN, DOUT)))
        mix = np.random.default_rng((seed, 31)).dirichlet(
            np.full(len(protos), alpha_client), size=(pods, devs, clients))
        w_cl = (0.5 * np.asarray(w_true)[:, None, None]
                + 0.5 * np.einsum("pdkm,mio->pdkio", mix, protos))
        rows = batch // clients
        xs_c = xs.reshape(steps, pods, devs, clients, rows, DIN)
        ys = jnp.einsum("spdkbi,pdkio->spdkbo", xs_c,
                        jnp.asarray(w_cl, xs.dtype)
                        ).reshape(steps, pods, devs, batch, DOUT)
    return {"w0": w0, "xs": xs, "ys": ys, "pods": pods, "devs": devs,
            "rounds": rounds, "t_e": t_e, "clients": clients}


def _algo(method, transport, state_layout, **kw):
    base = dict(method=method, mu=5e-3, mu_sgd=0.05, t_e=3, rho=1.0,
                transport=transport, state_layout=state_layout,
                compute_dtype=jnp.float32, master_dtype=jnp.float32,
                delta_dtype=jnp.float32)
    base.update(kw)
    return hier.AlgoConfig(**base)


def _fsdp_loss_master(params, delta, batch, rngs, lift):
    p_dev = lift(params, delta, FSDP_MASTER_SPECS, COMPUTE_SPECS)

    def one(pd, b, r):
        h = b["x"] @ pd["w"]
        pred = h @ pd["w2"] + pd["b"]
        return jnp.mean((pred - b["y"]) ** 2)

    losses = jax.vmap(jax.vmap(one))(p_dev, batch, rngs)
    return jnp.sum(losses), losses


def make_bundle(regime: str = "replicated") -> hier.ModelBundle:
    """The toy model's bundle for either regime (shared by the fast
    suite, the 8-device matrix check and the sharded fused check)."""
    if regime == "fsdp":
        return hier.ModelBundle(loss=None, compute_specs=COMPUTE_SPECS,
                                master_specs=FSDP_MASTER_SPECS,
                                loss_master=_fsdp_loss_master,
                                param_mode="fsdp")
    return hier.ModelBundle(loss=loss_fn, compute_specs=COMPUTE_SPECS,
                            master_specs=COMPUTE_SPECS)


def run_hier(topo: Topology, problem, method, transport="ag_packed",
             state_layout="tree", regime="replicated", mask=None,
             **algo_kw):
    """Run the full trajectory; returns the final edge-model pytree
    (plain numpy leaves, flat state unflattened) plus the edge weights
    used, so callers can cloud-aggregate for oracle comparison."""
    t_e = problem["t_e"]
    algo = _algo(method, transport, state_layout, t_e=t_e, **algo_kw)
    bundle = make_bundle(regime)
    init_fn, step = hier.make_hier_step(topo, algo, bundle)
    # init under jit: uneven model-sharded leaves (odd hid) only exist
    # as jit-produced arrays -- eager placement of uneven shardings is
    # unsupported -- and jit changes nothing for the even cells
    state = jax.jit(init_fn)(problem["w0"], jax.random.PRNGKey(1))
    pods, devs = problem["pods"], problem["devs"]
    ew = jnp.full((pods,), 1.0 / pods)
    dw = jnp.full((pods, devs), 1.0 / devs)
    maskf = jnp.ones((pods, devs)) if mask is None else jnp.asarray(mask)
    jstep = jax.jit(step)
    xs, ys = problem["xs"], problem["ys"]
    for s in range(problem["rounds"] * t_e):
        anchor = s - s % t_e
        batch = {"train": {"x": xs[s], "y": ys[s]},
                 "anchor": {"x": xs[anchor], "y": ys[anchor]}}
        state, _ = jstep(state, batch, ew, dw, maskf)
    params = (state.params.tree() if state_layout == "flat"
              else state.params)
    return jax.tree.map(np.asarray, params), np.asarray(ew)


def aggregate(params, edge_weights):
    """Cloud aggregation of the final edge models (the oracle's w)."""
    return jax.tree.map(
        lambda v: np.tensordot(edge_weights, np.asarray(v), axes=1),
        params)


def run_oracle(problem, method, mask=None, clients=None, cloud_period=2,
               cloud_overlap="sync", assignment=None):
    """ref_fed transcription of Algorithms 1/2 on the same trajectory.

    With an active ``clients`` ClientConfig the oracle hosts the same
    K virtual clients per slice as the distributed step: client c of
    slice d is oracle client d*K + c, its batch is the matching
    contiguous shard of the slice batch, the per-round participation
    mask comes from the SAME pinned (seed, round) scheme, |D_qk| weight
    the vote, and anchor/mean shares reweight to the participants.

    ``assignment`` (a ``data.cluster.assignment_order`` permutation)
    regroups the per-client batch/anchor lists through
    ``ref_fed.regroup_client_data`` -- the oracle-side half of the
    clustered-edge-assignment parity cells, compared against the
    distributed step fed ``regroup_problem``'s permuted arrays.

    Under ``cloud_overlap="overlap"`` the returned tree is the oracle's
    ``w_inflight`` -- the aggregate issued at the CLOSING boundary from
    the final edge models, i.e. the quantity comparable to
    ``aggregate(final distributed edge params, closing edge weights)``
    (the committed ``state.w`` lags one boundary behind it, mirroring
    ``TrainState.agg_next``)."""
    pods, devs, t_e = problem["pods"], problem["devs"], problem["t_e"]
    cfg = ref_fed.HierConfig(mu=5e-3, mu_sgd=0.05, t_e=t_e, rho=1.0,
                             method=method, cloud_period=cloud_period,
                             cloud_overlap=cloud_overlap)
    cc = clients or vclients.ClientConfig()
    k_c = cc.count
    state = ref_fed.init_state(problem["w0"], pods)
    grad_fn = lambda p, b, r: jax.grad(loss_fn)(p, b, r)
    xs, ys = problem["xs"], problem["ys"]
    b_cl = xs.shape[3] // k_c          # per-client batch rows

    def shard(a, s, q, dv):            # client dv's rows of step s
        d, c = divmod(dv, k_c)
        return a[s, q, d, c * b_cl:(c + 1) * b_cl]

    w_int = cc.weight_array(pods, devs).reshape(pods, devs * k_c)
    vote_w = [list(map(int, w_int[q])) for q in range(pods)]
    # unnormalized per-client shares: physical dev weight x |D_qk|
    dev_w = [[w_int[q][dv] * (1.0 / devs) for dv in range(devs * k_c)]
             for q in range(pods)]
    for t in range(problem["rounds"]):
        batches = [[[{"x": shard(xs, t * t_e + tau, q, dv),
                      "y": shard(ys, t * t_e + tau, q, dv)}
                     for tau in range(t_e)] for dv in range(devs * k_c)]
                   for q in range(pods)]
        anchors = [[{"x": shard(xs, t * t_e, q, dv),
                     "y": shard(ys, t * t_e, q, dv)}
                    for dv in range(devs * k_c)] for q in range(pods)]
        if assignment is not None:
            batches = ref_fed.regroup_client_data(batches, assignment,
                                                  pods)
            anchors = ref_fed.regroup_client_data(anchors, assignment,
                                                  pods)
        mask_t = None if mask is None else np.asarray(mask, bool)
        if cc.active:
            part = np.asarray(vclients.participation_mask(
                cc, pods, devs, t)) > 0.5                    # [P, D, K]
            if mask_t is not None:
                part = part & mask_t[:, :, None]
            mask_t = part.reshape(pods, devs * k_c)
        state = ref_fed.global_round(
            state, cfg, grad_fn, batches, anchors,
            [1.0 / pods] * pods,
            dev_w if cc.active else [[1.0 / devs] * devs] * pods,
            jax.random.PRNGKey(1),
            device_mask=None if mask_t is None else
            [list(row) for row in mask_t],
            vote_weights=vote_w if cc.active else None,
            reweight_participation=cc.active)
    out = state.w_inflight if cfg.cloud_schedule().staged else state.w
    return jax.tree.map(np.asarray, out)


# -- cluster-aware edge assignment: the two regrouping implementations
#    (distributed row-block permutation vs oracle nested-list
#    permutation) are pinned against each other by the clustered cells


def clustered_assignment(problem, clients: int) -> np.ndarray:
    """Mean-label-embedding sketches per virtual client (the [DOUT]
    average of the client's target rows -- an aggregate; no raw rows
    cross) -> the deterministic balanced clustering of ``data.cluster``
    -> the flat slot-order permutation regrouping the fleet's P*D*K
    clients into P pods by data similarity."""
    ys = np.asarray(problem["ys"])            # [S, P, D, b, DOUT]
    s, p, d, b, o = ys.shape
    rows = b // clients
    percl = ys.reshape(s, p * d * clients, rows, o).mean(axis=(0, 2))
    assign = cluster.cluster_edges(cluster.sketch_signatures(percl), p)
    return cluster.assignment_order(assign, p)


def regroup_problem(problem, order) -> dict:
    """The distributed-side regrouping: permute the per-client row
    blocks of every step's batch arrays via
    ``core.clients.regroup_clients`` (exactly the blocks the carve
    hands each voter).  ``run_oracle(assignment=order)`` is the
    oracle-side counterpart on the ORIGINAL problem."""
    k = problem["clients"]
    xs, ys = problem["xs"], problem["ys"]
    moved = [vclients.regroup_clients({"x": xs[s], "y": ys[s]}, order, k)
             for s in range(xs.shape[0])]
    out = dict(problem)
    out["xs"] = jnp.stack([m["x"] for m in moved])
    out["ys"] = jnp.stack([m["y"] for m in moved])
    return out


# -- chaos cells: membership churn schedules through the SAME runners --


def chaos_injector(pods, devs, k, t_e, nan_step=None):
    """The deterministic mixed-churn schedule of the chaos parity cells.

    Touches every membership path: a mid-round client kill, straggler
    demotion AT a round boundary, a heartbeat-loss sweep of a whole
    device (driving the edge through its fail-open window when P = 1),
    recoveries, and (for multi-pod problems) a pod loss spanning a round
    boundary.  ``nan_step`` adds a simulated numeric blow-up there
    (restore-and-replay through the checkpoint store)."""
    evs = [
        chaos.ChaosEvent(1, "client", 0, devs - 1, k - 1),
        chaos.ChaosEvent(t_e, "straggler", 0, 0, 0),
        chaos.ChaosEvent(t_e + 1, "recover", 0, devs - 1, k - 1),
        chaos.ChaosEvent(2 * t_e, "heartbeat", 0, 0),
        chaos.ChaosEvent(2 * t_e + 1, "recover", 0, 0),
        chaos.ChaosEvent(2 * t_e + 1, "recover", 0, 0, 0),
    ]
    if pods > 1:
        evs += [chaos.ChaosEvent(t_e + 2, "pod", 1),
                chaos.ChaosEvent(2 * t_e + 1, "recover", 1)]
    if nan_step is not None:
        evs.append(chaos.ChaosEvent(nan_step, "nan"))
    return chaos.FaultInjector(evs)


def chaos_arrays(problem, clients, injector):
    """Compile the schedule to per-step membership arrays (one extra
    entry past the horizon: the closing cloud aggregation of the final
    round reads the post-run edge weights)."""
    member = elastic.Membership(problem["pods"], problem["devs"],
                                clients=clients)
    steps = problem["rounds"] * problem["t_e"]
    return chaos.compile_schedule(injector, member, steps + 1)


def run_hier_chaos(topo, problem, method, transport="ag_packed",
                   state_layout="tree", clients=None, injector=None,
                   arrays=None, ckpt_dir=None, ckpt_every=None,
                   **algo_kw):
    """``run_hier`` under a chaos schedule: the membership arrays are
    fresh runtime inputs every step (client-granular [P, D, K] mask on
    the virtual path).  With ``ckpt_dir`` the driver checkpoints every
    ``ckpt_every`` steps and a scheduled ``nan`` event triggers
    restore-latest + replay (deterministic: cursor-addressable batches
    + compiled arrays).  Returns (final per-edge params, arrays)."""
    t_e = problem["t_e"]
    algo = _algo(method, transport, state_layout, t_e=t_e,
                 clients=clients, **algo_kw)
    init_fn, step = hier.make_hier_step(topo, algo, make_bundle())
    state = jax.jit(init_fn)(problem["w0"], jax.random.PRNGKey(1))
    steps = problem["rounds"] * t_e
    if arrays is None:
        arrays = chaos_arrays(problem, clients, injector)
    jstep = jax.jit(step)
    xs, ys = problem["xs"], problem["ys"]
    if ckpt_dir:
        store.save(ckpt_dir, 0, state)
    s = 0
    while s < steps:
        ew, dw, mask = arrays[s]
        anchor = s - s % t_e
        batch = {"train": {"x": xs[s], "y": ys[s]},
                 "anchor": {"x": xs[anchor], "y": ys[anchor]}}
        state, _ = jstep(state, batch, jnp.asarray(ew), jnp.asarray(dw),
                         jnp.asarray(mask))
        if injector is not None and injector.nan_due(s):
            assert ckpt_dir, "a nan event needs a checkpoint dir"
            s, state = store.restore_latest(ckpt_dir, state)
            continue
        s += 1
        if ckpt_dir and ckpt_every and s % ckpt_every == 0:
            store.save(ckpt_dir, s, state)
    params = (state.params.tree() if state_layout == "flat"
              else state.params)
    return jax.tree.map(np.asarray, params), arrays


def run_oracle_chaos(problem, method, clients, arrays, cloud_period=2,
                     cloud_overlap="sync"):
    """The grown ``ref_fed`` oracle under the SAME compiled schedule:
    per-tau vote masks (``device_mask_steps`` = pinned participation of
    round t AND the membership mask of step t*T_E + tau), round-prologue
    weights from the arrays at step t*T_E, and the closing aggregation
    at the NEXT round's edge weights (``edge_weights_agg``) -- exactly
    the distributed step's churn semantics.  ``edge_weights_agg`` is
    also the overlap schedule's ISSUE-time membership pin: the
    aggregate that leaves at a boundary lands one round later with the
    weights it left with, even when a pod dies mid-flight.  As in
    ``run_oracle``, overlap returns ``w_inflight``."""
    pods, devs, t_e = problem["pods"], problem["devs"], problem["t_e"]
    cfg = ref_fed.HierConfig(mu=5e-3, mu_sgd=0.05, t_e=t_e, rho=1.0,
                             method=method, cloud_period=cloud_period,
                             cloud_overlap=cloud_overlap)
    cc = clients
    k_c = cc.count
    state = ref_fed.init_state(problem["w0"], pods)
    grad_fn = lambda p, b, r: jax.grad(loss_fn)(p, b, r)
    xs, ys = problem["xs"], problem["ys"]
    b_cl = xs.shape[3] // k_c

    def shard(a, s, q, dv):
        d, c = divmod(dv, k_c)
        return a[s, q, d, c * b_cl:(c + 1) * b_cl]

    w_int = cc.weight_array(pods, devs).reshape(pods, devs * k_c)
    vote_w = [list(map(int, w_int[q])) for q in range(pods)]
    for t in range(problem["rounds"]):
        batches = [[[{"x": shard(xs, t * t_e + tau, q, dv),
                      "y": shard(ys, t * t_e + tau, q, dv)}
                     for tau in range(t_e)] for dv in range(devs * k_c)]
                   for q in range(pods)]
        anchors = [[{"x": shard(xs, t * t_e, q, dv),
                     "y": shard(ys, t * t_e, q, dv)}
                    for dv in range(devs * k_c)] for q in range(pods)]
        sampled = np.asarray(
            vclients.participation_mask(cc, pods, devs, t)) > 0.5

        def m_at(s):
            mm = np.asarray(arrays[s].mask) > 0.5        # [P, D, K]
            return (sampled & mm).reshape(pods, devs * k_c)

        mask_steps = [[list(row) for row in m_at(t * t_e + tau)]
                      for tau in range(t_e)]
        dwq = np.asarray(arrays[t * t_e].dev_weights)
        dev_w = [[float(w_int[q][dv]) * float(dwq[q][dv // k_c])
                  for dv in range(devs * k_c)] for q in range(pods)]
        state = ref_fed.global_round(
            state, cfg, grad_fn, batches, anchors,
            [float(x) for x in arrays[t * t_e].edge_weights],
            dev_w, jax.random.PRNGKey(1),
            device_mask=mask_steps[0],
            device_mask_steps=mask_steps,
            vote_weights=vote_w,
            reweight_participation=True,
            edge_weights_agg=[float(x)
                              for x in arrays[(t + 1) * t_e].edge_weights])
    out = state.w_inflight if cfg.cloud_schedule().staged else state.w
    return jax.tree.map(np.asarray, out)


# -- matrix definition (shared by the fast suite and the 8-device check)

SIGN_TRANSPORTS = ("ag_packed", "ar_int8", "fused")
LAYOUTS = ("tree", "flat")

# virtual-client axis: K x participation regime (ISSUE 5); "full" uses
# explicit unit weights so the ACTIVE machinery runs (the K=1 cell is
# then the headline bitwise-equals-legacy migration check)
CLIENT_REGIMES = ("full", "sampled", "fixed", "weighted",
                  "sampled_weighted")


def _share_weights(pods, devs, k):
    """Deterministic unequal |D_qk| in 1..5 (static nested tuples)."""
    return tuple(tuple(tuple((q + 2 * d + 3 * c) % 5 + 1
                             for c in range(k)) for d in range(devs))
                 for q in range(pods))


def client_cfg(pods: int, devs: int, k: int, regime: str,
               seed: int = 11) -> vclients.ClientConfig:
    """The shared ClientConfig of a (K, participation-regime) cell."""
    if regime == "full":
        return vclients.ClientConfig(
            count=k, weights=tuple(tuple(tuple(1 for _ in range(k))
                                         for _ in range(devs))
                                   for _ in range(pods)))
    if regime == "sampled":
        return vclients.ClientConfig(count=k, participation="bernoulli",
                                     rate=0.5, seed=seed)
    if regime == "fixed":
        return vclients.ClientConfig(count=k, participation="fixed",
                                     rate=0.5, seed=seed)
    if regime == "weighted":
        return vclients.ClientConfig(count=k,
                                     weights=_share_weights(pods, devs, k))
    if regime == "sampled_weighted":
        return vclients.ClientConfig(count=k, participation="bernoulli",
                                     rate=0.5, seed=seed,
                                     weights=_share_weights(pods, devs, k))
    raise ValueError(regime)


def matrix_cells():
    """Every supported replicated (method, transport, state_layout)."""
    cells = []
    for method in hier.SIGN_METHODS:
        for transport in SIGN_TRANSPORTS:
            for layout in LAYOUTS:
                cells.append((method, transport, layout))
    for method in ("hier_sgd", "hier_local_qsgd"):
        for layout in LAYOUTS:
            cells.append((method, "ag_packed", layout))
    return cells


def assert_trees_equal(a, b, tag, exact=True, atol=0.0):
    for k in a:
        x, y = np.asarray(a[k]), np.asarray(b[k])
        if exact:
            assert np.array_equal(x, y), (
                tag, k, float(np.max(np.abs(x - y))))
        else:
            np.testing.assert_allclose(x, y, atol=atol, rtol=0,
                                       err_msg=f"{tag}/{k}")
