"""Async checkpointing: device->host transfer on the caller, serialization
on a background thread, so training never blocks on disk I/O.

Usage:
    saver = AsyncSaver(ckpt_dir, keep=3)
    saver.submit(step, state)     # returns immediately
    saver.wait()                  # drain (end of run / before restore)
"""
from __future__ import annotations

import queue
import threading

import jax

from repro.checkpoint import store


class AsyncSaver:
    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._err: Exception | None = None
        self._t = threading.Thread(target=self._loop, daemon=True)
        self._t.start()

    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree = item
            try:
                store.save(self.ckpt_dir, step, host_tree, keep=self.keep)
            except Exception as e:  # surfaced on next submit/wait
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree):
        if self._err:
            raise self._err
        # synchronous device->host copy (cheap vs serialization), then
        # hand off to the writer thread.
        host = jax.tree.map(lambda x: jax.device_get(x), tree)
        self._q.put((step, host))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._t.join()
