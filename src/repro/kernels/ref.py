"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests).

These mirror ``repro.core.signs`` exactly; kernels are validated
element-wise against them over shape/dtype sweeps (interpret mode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import signs


def sign_pack_ref(g: jax.Array, delta: jax.Array | None, rho: float
                  ) -> jax.Array:
    """(g, delta) -> packed uint32 words; g/delta: [R, C], C % 32 == 0."""
    u = g.astype(jnp.float32)
    if delta is not None and rho:
        u = u + rho * delta.astype(jnp.float32)
    return signs.pack_signs(signs.sgn(u))


def vote_update_ref(packed: jax.Array, v: jax.Array, mu: float,
                    mask: jax.Array | None = None) -> jax.Array:
    """packed: [K, R, C/32] uint32; v: [R, C] f32 -> v - mu * vote.

    mask: optional [K] voter mask or integer vote weights -- the
    weighted-popcount / empty-quorum-abstains conventions come from
    ``signs.majority_vote_packed`` (matching the Pallas kernel)."""
    k, r, w = packed.shape
    c = v.shape[-1]
    vote = jax.vmap(
        lambda col: signs.majority_vote_packed(col, c, mask),
        in_axes=1, out_axes=0)(packed)          # [R, C]
    return v - mu * vote.astype(v.dtype)


def tally_acc_ref(u_buf: jax.Array, d_buf: jax.Array | None, rho: float,
                  weights: jax.Array, tally: jax.Array) -> jax.Array:
    """Streamed-client tally accumulate oracle (``kernels.tally_acc``).

    u_buf: [P, D, n] float pre-sign directions of ONE client; d_buf:
    [P, n] shared correction or None; weights: [P, D] integer vote
    weights; tally: [P, D, n] signed int tally.  Returns
    ``tally + w * sgn(u + rho*delta)`` with the product in int32 and
    the sign computed in f32 exactly like the kernel (and like
    ``sign_pack_ref``: ``x >= 0 -> +1``)."""
    u = u_buf.astype(jnp.float32)
    if d_buf is not None and rho:
        u = u + rho * d_buf[:, None].astype(jnp.float32)
    s = jnp.where(u >= 0, jnp.int32(1), jnp.int32(-1))
    add = weights.astype(jnp.int32)[:, :, None] * s
    return (tally.astype(jnp.int32) + add).astype(tally.dtype)


def ternary_quant_ref(x: jax.Array, u: jax.Array, norm: jax.Array
                      ) -> jax.Array:
    """Stochastic ternary quantizer given uniforms u and global l2 norm."""
    p = jnp.where(norm > 0, jnp.abs(x) / jnp.maximum(norm, 1e-30), 0.0)
    return jnp.where(u < p, norm * jnp.sign(x), 0.0).astype(x.dtype)
