"""Quickstart: train a reduced gemma3-1b under DC-HierSignSGD on CPU.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API end-to-end in ~40 lines: pick an assigned
architecture config, build the model for a topology, make the
hierarchical sign-SGD step, and train on the synthetic heterogeneous
token stream.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp

from repro import configs
from repro.core import hier
from repro.core.topology import single_device_topology
from repro.launch.train import RunCfg, run_training

cfg = configs.get_smoke("gemma3_1b")     # reduced same-family config
topo = single_device_topology()          # P=1 pod, D=1 device on CPU

algo = hier.AlgoConfig(
    method="dc_hier_signsgd",            # the paper's Algorithm 2
    mu=2e-3,                             # sign step size
    t_e=5,                               # local 1-bit steps per round
    rho=0.3,                             # correction strength
    compute_dtype=jnp.float32,
)

state, history = run_training(
    cfg, topo, algo,
    RunCfg(steps=30, batch_per_device=8, seq_len=64, log_every=5))

print(f"\nquickstart: loss {history[0]['loss']:.3f} -> "
      f"{history[-1]['loss']:.3f} over {len(history)} steps")
assert history[-1]["loss"] < history[0]["loss"]
print("OK")
