"""Mesh topology description shared by every distributed component.

The paper's hierarchy is mapped onto mesh axes (DESIGN.md Sec. 2):

    device k   -> one slice along the ``data``  axis   (inner, 1-bit tier)
    edge q     -> one slice along the ``pod``   axis   (outer, T_E tier)
    cloud      -> reduction over the ``pod`` axis
    TP/EP      -> the ``model`` axis (orthogonal to the paper's hierarchy)

``Topology`` carries the mesh + axis names and provides PartitionSpec /
sharding helpers so that core code never hard-codes axis names.  A
single-pod mesh simply has ``pod_axis=None`` (P=1).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Topology:
    mesh: Mesh
    pod_axis: str | None = "pod"
    data_axis: str = "data"
    model_axis: str = "model"

    @property
    def pods(self) -> int:
        if self.pod_axis is None:
            return 1
        return self.mesh.shape[self.pod_axis]

    @property
    def devices_per_pod(self) -> int:
        return self.mesh.shape[self.data_axis]

    @property
    def model_shards(self) -> int:
        return self.mesh.shape[self.model_axis]

    # -- spec builders -----------------------------------------------------
    def pod_spec(self, *rest) -> P:
        """Spec for per-edge state: leading pod dim + leaf dims."""
        return P(self.pod_axis, *rest)

    def dev_spec(self, *rest) -> P:
        """Spec for per-(edge, device) state: [P, D, ...]."""
        return P(self.pod_axis, self.data_axis, *rest)

    def batch_spec(self, *rest) -> P:
        """Global batch laid out as [P, D, local_b, ...]."""
        return P(self.pod_axis, self.data_axis, *rest)

    def client_spec(self, *rest) -> P:
        """Per-(edge, device, virtual-client) state: [P, D, K, ...].

        The K virtual clients of a physical slice (``core.clients``)
        live unsharded on their slice; merging them into the voter axis
        ([P, D*K, ...] under :meth:`dev_spec`) is a local reshape."""
        return P(self.pod_axis, self.data_axis, None, *rest)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, spec: P):
        return jax.lax.with_sharding_constraint(x, self.sharding(spec))

    def constrain_tree(self, tree, spec_tree):
        return jax.tree.map(
            lambda x, s: self.constrain(x, s), tree, spec_tree,
            is_leaf=lambda n: n is None)


def single_device_topology() -> Topology:
    """P=1, D=1, M=1 topology on the default device (tests / reference)."""
    dev = jax.devices()[0]
    mesh = Mesh(
        __import__("numpy").asarray([dev]).reshape(1, 1),
        ("data", "model"),
    )
    return Topology(mesh=mesh, pod_axis=None)
