"""Dry-run cost model for the Fig. 2-4 reproductions (the ``--fast`` CI
profile of ``benchmarks/run.py``).

Instead of training the EMNIST-like task on CPU (minutes per figure),
this module *prices* each cell analytically -- the same three-term
accounting the dry-run rooflines use (compute / memory / wire), scaled
to the reference simulator's Python-loop execution -- and derives the
reproduction quantity from the paper's Theorem 1/2 convergence
constants:

    C        = 2*zeta + 2*sigma*sqrt(d)/sqrt(B) + (1.5*T_E - 1)*L*mu
    C_dc(rho)= 2*(1-rho)*zeta + 2*sigma*sqrt(d)/sqrt(B)
               + ((3 + 8*rho)*T_E/2 - 1)*L*mu

(the same constants regression-tested in tests/test_ref_fed.py), mapped
onto a loss/accuracy proxy.  The rows carry the SAME names and the SAME
(name, us_per_call, derived) schema as the real-training profile, so
downstream JSON consumers cannot tell the profiles apart structurally
-- only the values are model-derived (each ``derived`` entry is tagged
``src=cost_model``).  Everything here completes in milliseconds.
"""
from __future__ import annotations

import numpy as np

# paper Table I / FedBenchCfg defaults
D_PARAMS = 784 * 64 + 64 + 64 * 10 + 10     # the EMNIST MLP (51018)
BATCH = 64
MU, MU_SGD = 5e-3, 0.5
Q_EDGES, DEVS = 4, 5
L_SMOOTH = 1.0
SIGMA = 0.05                                 # per-coordinate grad noise
ZETA_NONIID = 1.0                            # inter-edge dissimilarity
ZETA_IID = 0.05

# reference-simulator throughput model (Python-loop jax on one CPU core):
# grad flops ~ 6*d*B per device step, priced at an effective rate that
# is dominated by dispatch overhead in the ref_fed loop.
EFF_FLOPS = 2.0e9
DISPATCH_US = 350.0                          # per grad_fn/vote Python step
CLOUD_PERIOD = 2                             # mtgc eta refresh cadence


def participating_clients(clients_per_device: int = 1,
                          rate: float = 1.0) -> int:
    """Expected per-round participating client count of the fleet:
    Q_EDGES * DEVS physical slices x K virtual clients x Bernoulli(p)
    participation (at least one client votes -- an all-abstaining fleet
    costs nothing and prices nothing)."""
    return max(1, int(round(Q_EDGES * DEVS * clients_per_device * rate)))


def round_cost_us(method: str, t_e: int, clients_per_device: int = 1,
                  rate: float = 1.0) -> float:
    """Wall-time estimate of ONE ref_fed global round (all edges).

    Grad work (local steps + the DC anchor) scales with the
    PARTICIPATING client count, not the fleet size: masked-out virtual
    clients take no local step and send no uplink."""
    part = participating_clients(clients_per_device, rate)
    grad_calls = part * t_e
    # DC's anchor pass and the scaffold/mtgc control-variate refresh are
    # the same extra fleet-wide gradient evaluation at w^(t)
    anchor_calls = part if method in ("dc_hier_signsgd",
                                      "scaffold_hier_signsgd",
                                      "mtgc_hier_signsgd") else 0
    flops = 6.0 * D_PARAMS * BATCH * (grad_calls + anchor_calls)
    vote_steps = Q_EDGES * t_e
    return ((flops / EFF_FLOPS) * 1e6
            + (grad_calls + anchor_calls + vote_steps) * DISPATCH_US)


def _bound(method: str, rho: float, zeta: float, t_e: int) -> float:
    """Paper Thm 1/2 stationarity constant (sign methods) or the
    classical floors for the full-precision baselines."""
    noise = 2 * SIGMA * np.sqrt(D_PARAMS) / np.sqrt(BATCH)
    if method == "hier_signsgd":
        return 2 * zeta + noise + (1.5 * t_e - 1) * L_SMOOTH * MU
    if method == "dc_hier_signsgd":
        return (2 * (1 - rho) * zeta + noise
                + ((3 + 8 * rho) * t_e / 2 - 1) * L_SMOOTH * MU)
    if method == "scaffold_hier_signsgd":
        # control variates cancel the heterogeneity bias term entirely
        # but pay a larger client-drift constant than DC
        return noise + (5.5 * t_e - 1) * L_SMOOTH * MU
    if method == "mtgc_hier_signsgd":
        # two-timescale correction: the cloud term is stale by up to
        # cloud_period rounds, leaving a zeta residual DC does not have
        return (2 * zeta / CLOUD_PERIOD + noise
                + (2.5 * t_e - 1) * L_SMOOTH * MU)
    if method == "hier_sgd":        # unbiased: drift term only
        return 0.5 * zeta + (t_e - 1) * L_SMOOTH * MU_SGD * 0.1
    if method == "hier_local_qsgd":  # + quantizer variance inflation
        return 0.5 * zeta + (t_e - 1) * L_SMOOTH * MU_SGD * 0.1 + 0.3
    raise ValueError(method)


def _loss_proxy(c: float) -> float:
    return round(0.3 + 0.12 * c, 4)


def _acc_proxy(c: float) -> float:
    return round(1.0 / (1.0 + 0.25 * c), 4)


def fig2_rows(methods) -> list:
    rows = []
    for iid in (False, True):
        zeta = ZETA_IID if iid else ZETA_NONIID
        tag = "iid" if iid else "noniid"
        for m in methods:
            c = _bound(m, 0.2, zeta, 15)
            rows.append((f"fig2/{tag}/{m}", round_cost_us(m, 15),
                         f"final_acc={_acc_proxy(c)} src=cost_model"))
    return rows


def fig3_rows(te_values) -> list:
    rows = []
    for iid in (False, True):
        zeta = ZETA_IID if iid else ZETA_NONIID
        tag = "iid" if iid else "noniid"
        for te in te_values:
            for m in ("hier_signsgd", "dc_hier_signsgd"):
                c = _bound(m, 0.2, zeta, te)
                rows.append((f"fig3/{tag}/te{te}/{m}",
                             round_cost_us(m, te),
                             f"final_loss={_loss_proxy(c)} "
                             f"src=cost_model"))
    return rows


def clients_rows(cells=((64, 0.1),)) -> list:
    """Virtual-client scale-out rows (``--fast`` CI profile): K clients
    per device with Bernoulli(p) participation.  The per-round uplink is
    priced for the PARTICIPATING clients only (1 bit/coordinate/local
    step + the DC anchor, paper Table II per client), so the derived
    column makes the participation saving directly visible."""
    from repro.core.signs import uplink_bits
    rows = []
    for k, p in cells:
        part = participating_clients(k, p)
        for m in ("hier_signsgd", "dc_hier_signsgd"):
            # the fleet uplink is the per-slice expectation from
            # signs.uplink_bits (ONE accounting, shared with Table II)
            # scaled by the physical slice count
            bits = Q_EDGES * DEVS * uplink_bits(m, D_PARAMS, 15, clients=k,
                                                participation_rate=p)
            rows.append((f"clients/K{k}_p{p}/{m}",
                         round_cost_us(m, 15, k, p),
                         f"uplink_mbits_round={bits / 1e6:.1f} "
                         f"participants={part} src=cost_model"))
    return rows


def downlink_bits(method: str, d: int, t_e: int = 15,
                  cloud_period: int = CLOUD_PERIOD) -> float:
    """Per-round edge->device downlink bits per client for the
    drift-correction method axis.

    Every method broadcasts the fp32 edge model once per round (the
    T_E local steps re-use it); the corrections add:

      * dc:       the shared anchor delta c - c_q        (+32d)
      * scaffold: the shared c_global control variate    (+32d)
                  (c_local never travels -- it is born device-side)
      * mtgc:     the per-client gamma term every round  (+32d) and the
                  cloud-timescale eta term amortized over cloud_period
                  rounds                                 (+32d/period)
    """
    base = 32.0 * d
    if method == "hier_signsgd":
        return base
    if method == "dc_hier_signsgd":
        return base + 32.0 * d
    if method == "scaffold_hier_signsgd":
        return base + 32.0 * d
    if method == "mtgc_hier_signsgd":
        return base + 32.0 * d + 32.0 * d / cloud_period
    raise ValueError(method)


def methods_rows(t_e: int = 15, cloud_period: int = CLOUD_PERIOD) -> list:
    """Drift-correction method-axis rows (``--fast`` CI profile): the
    Thm-style stationarity proxy under severe heterogeneity next to the
    per-client downlink each correction costs."""
    rows = []
    for m in ("hier_signsgd", "dc_hier_signsgd", "scaffold_hier_signsgd",
              "mtgc_hier_signsgd"):
        c = _bound(m, 0.2, ZETA_NONIID, t_e)
        down = downlink_bits(m, D_PARAMS, t_e, cloud_period)
        rows.append((f"methods/{m}", round_cost_us(m, t_e),
                     f"final_loss={_loss_proxy(c)} "
                     f"downlink_kb_round={down / 8e3:.1f} "
                     f"src=cost_model"))
    return rows


def overlap_rows(t_e: int = 15, rtts=(1_000_000.0, 10_000_000.0)) -> list:
    """Cloud sync-schedule rows (``--fast`` CI profile): wall-clock per
    global round under each ``cloud_overlap`` mode as a function of the
    cloud round-trip.

      * ``sync``    -- the paper's barrier: the RTT sits on the
                       critical path, round = compute + RTT;
      * ``overlap`` -- the aggregate issued at one boundary commits at
                       the next, so the RTT hides behind a full round
                       of local stepping: round = max(compute, RTT),
                       and the RTT only surfaces once it exceeds the
                       compute of a round.

    ``hidden_frac`` is the fraction of the RTT taken off the critical
    path; ``speedup_vs_sync`` makes the saving directly comparable per
    (rtt, method) pair.  The default RTTs straddle the reference
    simulator's ~3 s round compute (a WAN cloud tier with stragglers):
    1 s hides completely, 10 s leaves the excess on the critical
    path."""
    rows = []
    for rtt in rtts:
        for m in ("hier_signsgd", "dc_hier_signsgd"):
            compute = round_cost_us(m, t_e)
            sync_us = compute + rtt
            lap_us = max(compute, rtt)
            hidden = min(compute, rtt) / rtt
            for sched, us in (("sync", sync_us), ("overlap", lap_us)):
                frac = hidden if sched == "overlap" else 0.0
                rows.append((
                    f"overlap/rtt{int(rtt / 1000)}ms/{sched}/{m}", us,
                    f"cloud_rtt_ms={rtt / 1000:.0f} "
                    f"hidden_frac={frac:.2f} "
                    f"speedup_vs_sync={sync_us / us:.2f} "
                    f"src=cost_model"))
    return rows


def fig4_rows(rhos) -> list:
    rows = []
    for rho in rhos:
        c = _bound("dc_hier_signsgd", rho, ZETA_NONIID, 15)
        rows.append((f"fig4/rho{rho}",
                     round_cost_us("dc_hier_signsgd", 15),
                     f"final_loss={_loss_proxy(c)} src=cost_model"))
    return rows
