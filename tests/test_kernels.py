"""Pallas kernel validation: interpret-mode vs pure-jnp oracles, swept over
shapes, dtypes, voter counts and masks (per-kernel allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import signs
from repro.kernels import ops, ref

BK = dict(block_r=8, block_c=128)
SHAPES = [(257,), (64, 129), (5, 7, 11), (4096,)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("rho", [0.0, 0.3])
def test_sign_pack_matches_oracle(shape, dtype, rho):
    g = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    d = (jax.random.normal(jax.random.PRNGKey(1), shape, dtype)
         if rho else None)
    packed, n = ops.sign_pack_nd(g, d, rho, use_pallas=True,
                                 interpret=True, **BK)
    u = g.astype(jnp.float32)
    if d is not None:
        u = u + rho * d.astype(jnp.float32)
    expect = signs.pack_signs(signs.sgn(u.reshape(-1)))
    assert n == int(np.prod(shape))
    got_bits = np.asarray(signs.unpack_signs(packed[: expect.shape[0]], n))
    exp_bits = np.asarray(signs.unpack_signs(expect, n))
    mism = np.where(got_bits != exp_bits)[0]
    # FMA contraction may flip the sign of coords where g + rho*d rounds
    # to exactly 0 -- tolerate only those ULP-boundary cases
    uf = np.abs(np.asarray(u.reshape(-1)))
    assert all(uf[i] < 1e-6 for i in mism), (mism, uf[mism])


@pytest.mark.parametrize("shape", [(333,), (64, 64)])
@pytest.mark.parametrize("k", [1, 4, 5, 16])
def test_vote_update_matches_oracle(shape, k):
    rng = jax.random.PRNGKey(2)
    gs = jax.random.normal(rng, (k,) + shape)
    rows = jnp.stack([ops.sign_pack_nd(gs[i], None, 0.0, use_pallas=True,
                                       interpret=True, **BK)[0]
                      for i in range(k)])
    v = jax.random.normal(jax.random.fold_in(rng, 1), shape)
    out = ops.vote_update_nd(rows, v, mu=0.05, use_pallas=True,
                             interpret=True, **BK)
    vote = signs.majority_vote(
        signs.sgn(gs.reshape(k, -1).astype(jnp.float32)), axis=0)
    expect = (v.reshape(-1) - 0.05 * vote).reshape(shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-6)


@pytest.mark.parametrize("mask", [[1, 1, 0], [0, 1, 0], [1, 1, 1]])
def test_vote_update_mask(mask):
    k = len(mask)
    gs = jax.random.normal(jax.random.PRNGKey(3), (k, 200))
    rows = jnp.stack([ops.sign_pack_nd(gs[i], None, 0.0, use_pallas=True,
                                       interpret=True, **BK)[0]
                      for i in range(k)])
    v = jnp.zeros((200,))
    out = ops.vote_update_nd(rows, v, jnp.asarray(mask, jnp.float32),
                             mu=1.0, use_pallas=True, interpret=True, **BK)
    vote = signs.majority_vote(signs.sgn(gs), jnp.asarray(mask)[:, None],
                               axis=0)
    np.testing.assert_allclose(np.asarray(out), -np.asarray(vote),
                               rtol=1e-6)


@pytest.mark.parametrize("shape", [(500,), (32, 48)])
def test_ternary_quant_matches_ref(shape):
    x = jax.random.normal(jax.random.PRNGKey(4), shape)
    q_k = ops.ternary_quant_nd(x, jax.random.PRNGKey(5), use_pallas=True,
                               interpret=True, **BK)
    q_r = ops.ternary_quant_nd(x, jax.random.PRNGKey(5), use_pallas=False,
                               **BK)
    np.testing.assert_allclose(np.asarray(q_k), np.asarray(q_r), rtol=1e-5)


def test_kernel_pipeline_roundtrip():
    """device compress -> edge vote+update == core.signs semantics."""
    k, n = 7, 1000
    gs = jax.random.normal(jax.random.PRNGKey(6), (k, n))
    delta = jax.random.normal(jax.random.PRNGKey(7), (n,))
    rows = jnp.stack([ops.sign_pack_nd(gs[i], delta, 0.2, use_pallas=True,
                                       interpret=True, **BK)[0]
                      for i in range(k)])
    v = jnp.ones((n,))
    out = ops.vote_update_nd(rows, v, mu=0.1, use_pallas=True,
                             interpret=True, **BK)
    s = signs.sgn(gs + 0.2 * delta[None])
    vote = signs.majority_vote(s, axis=0)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(1.0 - 0.1 * vote), rtol=1e-6)


@pytest.mark.parametrize("n", [4096, 8192])
@pytest.mark.parametrize("rho", [0.0, 0.2])
@pytest.mark.parametrize("acc_dtype", [jnp.int8, jnp.int16, jnp.int32])
def test_fused_tally_acc_matches_ref(n, rho, acc_dtype):
    """Streamed-client accumulate (pack->popcount->tally RMW fused into
    one pass) vs the pure-jnp oracle, swept over tally dtypes and the
    shared-correction fold."""
    p, d = 2, 3
    key = jax.random.PRNGKey(8)
    u = jax.random.normal(key, (p, d, n))
    db = (jax.random.normal(jax.random.fold_in(key, 1), (p, n))
          if rho else None)
    w = jax.random.randint(jax.random.fold_in(key, 2), (p, d), 0, 5)
    tally = jax.random.randint(jax.random.fold_in(key, 3), (p, d, n),
                               -20, 20).astype(acc_dtype)
    got = ops.fused_tally_acc_flat(u, db, rho, w, tally, interpret=True)
    expect = ref.tally_acc_ref(u, db, rho, w, tally)
    assert got.dtype == acc_dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_fused_tally_acc_accumulates_to_merged_vote():
    """Folding K clients through the kernel then thresholding the tally
    equals the merged weighted vote of the same K sign planes."""
    from repro.core import votes
    p, d, k, n = 1, 2, 6, 4096
    key = jax.random.PRNGKey(9)
    us = jax.random.normal(key, (k, p, d, n))
    ws = jax.random.randint(jax.random.fold_in(key, 1), (k, p, d), 0, 3)
    tally = jnp.zeros((p, d, n), jnp.int8)
    for c in range(k):
        tally = ops.fused_tally_acc_flat(us[c], None, 0.0, ws[c], tally,
                                         interpret=True)
    n_eff = jnp.sum(ws.astype(jnp.int32), axis=(0, 2))
    vote = votes.tally_vote(jnp.sum(tally.astype(jnp.int32), axis=1),
                            n_eff)
    s_merged = signs.sgn(us.transpose(1, 0, 2, 3).reshape(p, k * d, n))
    w_merged = ws.transpose(1, 0, 2).reshape(p, k * d)
    from repro.core.topology import single_device_topology
    merged = votes.vote_ar_int8(single_device_topology(), s_merged,
                                w_merged, weight_bound=int(n_eff.max()))
    np.testing.assert_array_equal(np.asarray(vote), np.asarray(merged))
