"""Flat-buffer gradient bucketization: one contiguous view of a pytree.

The sign->pack->vote->update sweep is elementwise and coordinate-order
agnostic, so running it per-leaf under ``jax.tree.map`` only buys N small
dispatches, N ragged pads, and N tiny collectives.  This module precomputes
a **static leaf layout** for any float pytree so the hot path can operate on
ONE contiguous ``[..., n_pad]`` buffer (or its 1-bit packed twin) instead:

  * every leaf is assigned a coordinate range ``[offset, offset + size)``
    with ``offset % 32 == 0`` (leaf tails padded to the 32-bit pack word),
    so the float and packed-word domains share the same layout:
    leaf i's words are exactly ``[offset/32, (offset + padded)/32)``;
  * the total is padded to the 32*128 TPU tile (one packed word per lane),
    so 2D views handed to the Pallas kernels need no further padding;
  * dtype promotion rule: the buffer dtype is ``jnp.promote_types`` over
    all leaf dtypes (float leaves only) -- promotion is widening, so
    ``unflatten_tree(flatten_tree(t))`` restores every leaf bit-exactly.

``flatten_tree``/``unflatten_tree`` are cheap reshape/slice views around a
single concatenate (unflatten is pure views); ``pack_tree`` fuses the DC
correction ``u + rho*delta`` and the sign into the per-leaf pack and
concatenates at the *word* level, so the full-precision buffer is never
materialized on the fallback path (the wire payload is 1/32 the tally).

Padding convention: float padding is 0 and ``sgn(0) = +1``, bit-identical
to ``signs.pack_signs``'s all-ones tail bits -- so
``pack_tree(layout, t) == pack_signs(sgn(flatten_tree(layout, t)))``
holds bitwise (tested in tests/test_flatbuf.py).

State layouts
-------------
PR 1 used the flat buffer only as a *transient* inside the fused
transport; with ``AlgoConfig(state_layout="flat")`` (``core.hier``) the
buffer becomes the *persistent* master state.  :class:`FlatState` wraps
one ``[*batch, n_pad]`` buffer together with its static
:class:`FlatLayout` as a single pytree node (the layout rides in the
treedef aux data, so jit/eval_shape/checkpoint traversals see exactly
one array leaf).  Under ``state_layout="flat"``:

  * ``TrainState.params`` / ``delta`` / ``delta_next`` are
    ``FlatState([P, n_pad])`` buffers (master / delta dtype), and the
    replicated-regime EF / momentum buffers are ``FlatState([P, D,
    n_pad])`` -- the whole-model update and the pre-sign correction
    ``u + rho*delta`` are single elementwise sweeps instead of per-leaf
    tree maps;
  * leaf views are materialized only at the loss-function boundary and
    at checkpoint/eval edges via :meth:`FlatState.tree`
    (``unflatten_tree`` is pure slice/reshape views);
  * coordinates beyond each leaf's ``size`` (tail + tile padding) are
    *don't-care*: the fused vote/update kernel sweeps them along with
    the real coordinates (their gradient is 0 -> vote +1, so they
    drift), but no view ever reads them and ``checkpoint.store``
    round-trips only the real coordinates.

The layout of a given tree is deterministic (flatten order x the rules
above), so two runs -- or a tree-state checkpoint and a flat-state run
-- always agree on where every leaf lives.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import signs

PyTree = Any

PACK = signs.PACK_WIDTH          # 32 sign bits per uint32 word
LANES = 128                      # TPU lane count
TILE = PACK * LANES              # 4096 coords = 128 packed words


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Static placement of one leaf inside the flat buffer."""
    shape: tuple[int, ...]       # leaf dims (batch dims excluded)
    dtype: Any                   # original leaf dtype (restored on unflatten)
    size: int                    # prod(shape)
    padded: int                  # size padded to a PACK multiple
    offset: int                  # coordinate offset; offset % PACK == 0

    @property
    def word_offset(self) -> int:
        return self.offset // PACK

    @property
    def words(self) -> int:
        return self.padded // PACK


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Static layout of a pytree as one tile-aligned flat buffer."""
    treedef: Any
    slots: tuple[LeafSlot, ...]
    n: int                       # real coordinates (sum of slot sizes)
    n_pad: int                   # buffer length; n_pad % TILE == 0
    dtype: Any                   # promoted float dtype of the flat buffer

    @property
    def n_words(self) -> int:
        return self.n_pad // PACK


@jax.tree_util.register_pytree_node_class
class FlatState:
    """One flat buffer + its static :class:`FlatLayout`, as a pytree node.

    The buffer is the single array leaf; ``(layout, batch_dims)`` ride in
    the treedef aux data, so the layout is available statically wherever
    the state travels (train step, eval_shape, checkpoint store) and two
    ``FlatState``s with the same layout are structure-compatible under
    ``jax.tree`` transforms, ``lax.cond`` and donation.
    """

    __slots__ = ("buf", "layout", "batch_dims")

    def __init__(self, buf, layout: FlatLayout, batch_dims: int = 1):
        self.buf = buf
        self.layout = layout
        self.batch_dims = batch_dims

    def tree(self, cast: bool = True) -> PyTree:
        """Materialize the leaf views (slice/reshape, no copy)."""
        return unflatten_tree(self.layout, self.buf,
                              batch_dims=self.batch_dims, cast=cast)

    def replace(self, buf) -> "FlatState":
        return FlatState(buf, self.layout, self.batch_dims)

    def tree_flatten(self):
        return (self.buf,), (self.layout, self.batch_dims)

    @classmethod
    def tree_unflatten(cls, aux, children):
        layout, batch_dims = aux
        return cls(children[0], layout, batch_dims)

    def __repr__(self):
        return (f"FlatState(buf={getattr(self.buf, 'shape', self.buf)!r}, "
                f"n={self.layout.n}, n_pad={self.layout.n_pad}, "
                f"batch_dims={self.batch_dims})")


def from_tree(tree: PyTree, batch_dims: int = 0,
              dtype: Any = None) -> FlatState:
    """Lay out and flatten ``tree`` into a :class:`FlatState` in one call."""
    layout = make_layout(tree, batch_dims=batch_dims)
    buf = flatten_tree(layout, tree, batch_dims=batch_dims, dtype=dtype)
    return FlatState(buf, layout, batch_dims)


def with_dtype(layout: FlatLayout, dtype: Any) -> FlatLayout:
    """The same coordinate layout, re-labeled for a buffer of ``dtype``.

    Auxiliary flat-state buffers (DC delta, EF residual, momentum) share
    the master's slot geometry but store a different dtype; their slots
    must say so, or ``FlatState.tree()`` / checkpoint metadata would
    report the master dtype for them.
    """
    dtype = jnp.dtype(dtype)
    slots = tuple(dataclasses.replace(s, dtype=dtype) for s in layout.slots)
    return dataclasses.replace(layout, slots=slots, dtype=dtype)


def make_layout(tree: PyTree, batch_dims: int = 0,
                tile: int = TILE) -> FlatLayout:
    """Compute the static layout of ``tree`` (shapes/dtypes only).

    batch_dims: number of leading dims shared by every leaf (e.g. 2 for
    ``[P, D, *leaf]`` per-device gradients) that stay un-flattened.
    """
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("cannot lay out an empty pytree")
    slots = []
    offset = 0
    dtype = None
    kinds = set()
    for leaf in leaves:
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            kinds.add("float")
        elif jnp.issubdtype(leaf.dtype, jnp.signedinteger):
            kinds.add("int")
        else:
            raise ValueError(
                "flatbuf only buckets float / signed-int leaves, got "
                f"{leaf.dtype}")
    if len(kinds) > 1:
        # jnp.promote_types(int32, bfloat16) == bfloat16 -- NOT widening,
        # so a mixed buffer could corrupt int values; keep trees
        # dtype-kind homogeneous (sign trees are all-int, grads all-float)
        raise ValueError("flatbuf trees must not mix int and float leaves")
    for leaf in leaves:
        shape = tuple(leaf.shape[batch_dims:])
        size = int(functools.reduce(lambda a, b: a * b, shape, 1))
        padded = _ceil_to(max(size, 1), PACK)
        slots.append(LeafSlot(shape=shape, dtype=leaf.dtype, size=size,
                              padded=padded, offset=offset))
        offset += padded
        dtype = (leaf.dtype if dtype is None
                 else jnp.promote_types(dtype, leaf.dtype))
    n = sum(s.size for s in slots)
    return FlatLayout(treedef=treedef, slots=tuple(slots), n=n,
                      n_pad=_ceil_to(offset, tile), dtype=jnp.dtype(dtype))


def _flat_leaf(slot: LeafSlot, leaf: jax.Array, batch_dims: int):
    batch = leaf.shape[:batch_dims]
    flat = leaf.reshape(batch + (slot.size,))
    if slot.padded != slot.size:
        flat = jnp.pad(flat, [(0, 0)] * batch_dims
                       + [(0, slot.padded - slot.size)])
    return flat


def flatten_tree(layout: FlatLayout, tree: PyTree, batch_dims: int = 0,
                 dtype: Any = None) -> jax.Array:
    """tree -> ``[*batch, n_pad]`` buffer in the (promoted) buffer dtype."""
    dtype = layout.dtype if dtype is None else dtype
    leaves = layout.treedef.flatten_up_to(tree)
    parts = [_flat_leaf(s, leaf.astype(dtype), batch_dims)
             for s, leaf in zip(layout.slots, leaves)]
    buf = jnp.concatenate(parts, axis=-1)
    tail = layout.n_pad - buf.shape[-1]
    if tail:
        buf = jnp.pad(buf, [(0, 0)] * batch_dims + [(0, tail)])
    return buf


def unflatten_tree(layout: FlatLayout, buf: jax.Array, batch_dims: int = 0,
                   cast: bool = True) -> PyTree:
    """``[*batch, n_pad]`` buffer -> pytree of slice views.

    cast=True restores each leaf's original dtype (exact for widening
    promotions); cast=False keeps ``buf.dtype`` (e.g. int8 vote bits).
    """
    batch = buf.shape[:batch_dims]
    leaves = []
    for s in layout.slots:
        leaf = buf[..., s.offset:s.offset + s.size].reshape(batch + s.shape)
        leaves.append(leaf.astype(s.dtype) if cast else leaf)
    return layout.treedef.unflatten(leaves)


def _with_mid_axes(x: jax.Array, batch_dims: int, target_batch: int):
    """[*b, n] -> [*b, 1...1, n] broadcastable against target_batch dims."""
    for _ in range(target_batch - batch_dims):
        x = x[..., None, :]
    return x


def pack_tree(layout: FlatLayout, tree: PyTree, batch_dims: int = 0,
              delta: PyTree | None = None, rho: float = 0.0,
              delta_batch_dims: int = 0) -> jax.Array:
    """Fused (u + rho*delta) -> sign -> 1-bit pack, concatenated per word.

    Returns ``[*batch, n_pad/32]`` uint32.  The correction is added in each
    leaf's own dtype -- exactly ``u + rho * delta.astype(u.dtype)``, the
    same arithmetic the per-leaf tree path uses -- so votes stay
    bit-identical to the ``ag_packed`` transport.  Word concatenation means
    the full-precision flat buffer never exists: only the 1-bit payload is
    contiguous.  Tail words are all-ones (+1 signs), matching
    ``pack_signs`` padding.
    """
    leaves = layout.treedef.flatten_up_to(tree)
    dl_leaves = (layout.treedef.flatten_up_to(delta)
                 if delta is not None else [None] * len(leaves))
    parts = []
    for slot, leaf, dl in zip(layout.slots, leaves, dl_leaves):
        u = leaf.reshape(leaf.shape[:batch_dims] + (slot.size,))
        if slot.size == 0:
            # pack_signs pads to ceil(size/32) words == 0 for empty
            # leaves, but the slot still occupies `words` all-padding
            # words (+1 signs) so later offsets stay aligned.
            parts.append(jnp.full(leaf.shape[:batch_dims] + (slot.words,),
                                  0xFFFFFFFF, jnp.uint32))
            continue
        if dl is not None and rho:
            dlf = dl.reshape(dl.shape[:delta_batch_dims] + (slot.size,))
            dlf = _with_mid_axes(dlf, delta_batch_dims, batch_dims)
            u = u + rho * dlf.astype(u.dtype)
        parts.append(signs.pack_signs(signs.sgn(u)))      # pads to +1 bits
    words = jnp.concatenate(parts, axis=-1)
    tail = layout.n_words - words.shape[-1]
    if tail:
        words = jnp.pad(words, [(0, 0)] * batch_dims + [(0, tail)],
                        constant_values=jnp.uint32(0xFFFFFFFF))
    return words
