"""Build ArchDefs + train/serve entry points from an LMConfig.

This is the public model API used by launch/, tests/ and examples/:

    built = build_model(cfg, topo, algo)
    built.init_params(rng)        -> single-replica params
    built.bundle                  -> repro.core.hier.ModelBundle
    built.make_cache(b, max_len)  -> decode cache
    built.prefill / built.decode_step
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import hier
from repro.core.topology import Topology
from repro.models import attention as attn_mod
from repro.models import blocks as B
from repro.models import engine, layers
from repro.models.blocks import Ctx
from repro.models.config import LMConfig
from repro.models.engine import ArchDef, FsdpPlan, ReplicatedPlan, Segment

PyTree = Any

# REPRO_DISABLE_OPT=1 turns off the beyond-paper perf changes (head/resid
# layout pinning, serve-resident weights) for A/B roofline measurement.
import os
_DISABLE_OPT = os.environ.get("REPRO_DISABLE_OPT", "") == "1"


# ---------------------------------------------------------------------------
# schedules per family
# ---------------------------------------------------------------------------

def make_archdef(cfg: LMConfig, model_shards: int) -> ArchDef:
    f = cfg.family
    if f in ("dense", "vlm"):
        if cfg.local_global:
            loc, glob = cfg.local_global
            period = loc + glob
            groups = cfg.n_layers // period
            rem = cfg.n_layers - groups * period
            blocks = {
                "local": B.dense_block(cfg, model_shards, window=cfg.window,
                                       theta=cfg.rope_theta, name="local"),
                "global": B.dense_block(cfg, model_shards, theta=cfg.
                                        rope_theta_global, name="global"),
            }
            segments = [Segment((("local", loc), ("global", glob)), groups)]
            if rem:
                segments.append(Segment((("local", rem),), 1))
            return ArchDef(cfg, blocks, segments)
        blocks = {"dense": B.dense_block(cfg, model_shards)}
        return ArchDef(cfg, blocks,
                       [Segment((("dense", 1),), cfg.n_layers)])

    if f == "moe":
        use_mla = cfg.mla is not None
        blocks = {"moe": B.moe_block(cfg, model_shards, use_mla=use_mla)}
        segments = []
        n_moe = cfg.n_layers
        if cfg.moe.first_dense:
            if use_mla:
                blocks["dense"] = B.mla_dense_block(
                    cfg, model_shards, cfg.moe.dense_ff)
            else:
                blocks["dense"] = B.dense_block(
                    cfg, model_shards, d_ff=cfg.moe.dense_ff)
            segments.append(Segment((("dense", 1),), cfg.moe.first_dense))
            n_moe -= cfg.moe.first_dense
        segments.append(Segment((("moe", 1),), n_moe))
        mtp = None
        if cfg.mtp:
            mtp = (B.mla_dense_block(cfg, model_shards, cfg.moe.dense_ff,
                                     name="mtp") if use_mla else
                   B.dense_block(cfg, model_shards, name="mtp"))
        return ArchDef(cfg, blocks, segments, mtp_block=mtp)

    if f == "hybrid":  # zamba2: mamba stacks + tied shared attention block
        every = cfg.ssm.attn_every
        groups = cfg.n_layers // every
        rem = cfg.n_layers - groups * every
        blocks = {
            "mamba": B.mamba_block(cfg, model_shards),
            "shared_attn": B.dense_block(cfg, model_shards,
                                         name="shared_attn"),
        }
        segments = [Segment((("mamba", every), ("shared_attn", 1)), groups,
                            tied=frozenset({"shared_attn"}))]
        if rem:
            segments.append(Segment((("mamba", rem),), 1))
        return ArchDef(cfg, blocks, segments)

    if f == "ssm":  # xlstm: m_per_s mLSTM + 1 sLSTM per group
        m = cfg.xlstm.m_per_s
        period = m + 1
        groups = cfg.n_layers // period
        rem = cfg.n_layers - groups * period
        blocks = {"mlstm": B.mlstm_block(cfg, model_shards),
                  "slstm": B.slstm_block(cfg, model_shards)}
        segments = [Segment((("mlstm", m), ("slstm", 1)), groups)]
        if rem:
            segments.append(Segment((("mlstm", rem),), 1))
        return ArchDef(cfg, blocks, segments)

    if f in ("encdec", "audio"):  # whisper
        enc_blocks = {"enc": B.dense_block(cfg, model_shards, causal=False,
                                           name="enc")}
        dec_blocks = {"dec": B.dense_block(cfg, model_shards, cross=True,
                                           name="dec")}
        return ArchDef(
            cfg, dec_blocks, [Segment((("dec", 1),), cfg.n_layers)],
            enc_blocks=enc_blocks,
            enc_segments=[Segment((("enc", 1),), cfg.encoder_layers)])

    raise ValueError(f"unknown family {f}")


# ---------------------------------------------------------------------------
# param init + specs
# ---------------------------------------------------------------------------

def init_params(arch: ArchDef, rng: jax.Array) -> PyTree:
    cfg = arch.cfg
    ks = iter(jax.random.split(rng, 16))
    params: dict = {"embed": layers.init_embed(next(ks), cfg.vocab,
                                               cfg.d_model)}
    counts = engine.stack_counts(arch.segments)
    params["stacks"] = {
        name: engine._stack_init(arch.blocks[name], next(ks), n)
        for name, n in counts.items()}
    if arch.enc_segments:
        ecounts = engine.stack_counts(arch.enc_segments)
        params["enc_stacks"] = {
            name: engine._stack_init(arch.enc_blocks[name], next(ks), n)
            for name, n in ecounts.items()}
        params["adapter"] = {
            "w": layers.he_init(next(ks), (cfg.frontend_dim, cfg.d_model))}
    head = {"norm": layers.init_rms(next(ks), cfg.d_model)}
    if not cfg.tie_embed:
        head["out"] = layers.he_init(next(ks), (cfg.d_model, cfg.vocab))
    params["head"] = head
    if arch.mtp_block is not None:
        params["mtp"] = {
            "proj": layers.he_init(next(ks), (2 * cfg.d_model, cfg.d_model)),
            "n_x": layers.init_rms(next(ks), cfg.d_model),
            "n_e": layers.init_rms(next(ks), cfg.d_model),
            "block": arch.mtp_block.init(next(ks)),
        }
    return params


def compute_specs(arch: ArchDef, model_shards: int = 0) -> PyTree:
    cfg = arch.cfg
    specs: dict = {"embed": layers.embed_specs(cfg.vocab, model_shards)}
    specs["stacks"] = {}
    counts = engine.stack_counts(arch.segments)
    for name, n in counts.items():
        bs = arch.blocks[name].specs
        specs["stacks"][name] = engine._prepend(bs, None) if n else bs
    if arch.enc_segments:
        specs["enc_stacks"] = {
            name: engine._prepend(arch.enc_blocks[name].specs, None)
            for name in engine.stack_counts(arch.enc_segments)}
        specs["adapter"] = {"w": P(None, None)}
    head = {"norm": P(None)}
    if not cfg.tie_embed:
        ok = model_shards and cfg.vocab % model_shards == 0
        head["out"] = P(None, "model" if ok else None)
    specs["head"] = head
    if arch.mtp_block is not None:
        specs["mtp"] = {"proj": P(None, None), "n_x": P(None),
                        "n_e": P(None), "block": arch.mtp_block.specs}
    return specs


def fsdpify_leaf(spec: P, shape: tuple, d_shards: int, m_shards: int,
                 skip_lead: int = 0) -> P:
    """Insert 'data' sharding into one suitable dim of a compute spec."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i in range(skip_lead, len(shape)):
        if entries[i] is None and shape[i] % max(d_shards, 1) == 0 \
                and shape[i] >= d_shards:
            entries[i] = "data"
            return P(*entries)
    for i in range(skip_lead, len(shape)):
        if entries[i] == "model" and shape[i] % (d_shards * m_shards) == 0:
            entries[i] = ("model", "data")
            return P(*entries)
    return P(*entries)


def build_master_specs(arch: ArchDef, cspecs: PyTree, shapes: PyTree,
                       topo: Topology, fsdp: bool):
    """Returns (full master specs, per-block per-LAYER master specs).

    Full specs mirror the param tree (leaf dims only, no pod dim); layer
    specs are what FsdpPlan hands to fsdp_lift after scan slicing strips
    the stack dim.
    """
    if not fsdp:
        per_block = {name: arch.blocks[name].specs for name in arch.blocks}
        return cspecs, per_block
    d, m = topo.devices_per_pod, topo.model_shards
    is_p = lambda x: isinstance(x, P)

    def fsdpify_tree(spec_tree, shape_tree, skip_lead=0):
        return jax.tree.map(
            lambda s, shp: fsdpify_leaf(s, shp.shape, d, m, skip_lead),
            spec_tree, shape_tree, is_leaf=is_p)

    full: dict = {}
    per_block: dict = {}
    counts = engine.stack_counts(arch.segments)
    full["stacks"] = {}
    for name, n in counts.items():
        bd = arch.blocks[name]
        if n:  # stacked: derive per-layer spec from per-layer shapes
            layer_shapes = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                shapes["stacks"][name])
            layer_spec = fsdpify_tree(bd.specs, layer_shapes)
            per_block[name] = layer_spec
            full["stacks"][name] = engine._prepend(layer_spec, None)
        else:  # tied: params are already per-layer
            layer_spec = fsdpify_tree(bd.specs, shapes["stacks"][name])
            per_block[name] = layer_spec
            full["stacks"][name] = layer_spec
    full["embed"] = fsdpify_tree(cspecs["embed"], shapes["embed"])
    full["head"] = fsdpify_tree(cspecs["head"], shapes["head"])
    if "adapter" in cspecs:
        full["adapter"] = fsdpify_tree(cspecs["adapter"], shapes["adapter"])
    if "enc_stacks" in cspecs:
        full["enc_stacks"] = {}
        for name in cspecs["enc_stacks"]:
            bd = arch.enc_blocks[name]
            layer_shapes = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                shapes["enc_stacks"][name])
            layer_spec = fsdpify_tree(bd.specs, layer_shapes)
            per_block[name] = layer_spec
            full["enc_stacks"][name] = engine._prepend(layer_spec, None)
    if "mtp" in cspecs:
        full["mtp"] = fsdpify_tree(cspecs["mtp"], shapes["mtp"])
    return full, per_block


def occurrence_counts(segments) -> dict[str, int]:
    occ: dict[str, int] = {}
    for seg in segments:
        for bname, cnt in seg.layout:
            occ[bname] = occ.get(bname, 0) + cnt * seg.repeats
    return occ


# ---------------------------------------------------------------------------
# loss assembly (shared pieces)
# ---------------------------------------------------------------------------

def _targets_and_mask(tokens):
    """Next-token LM targets with the final position masked out."""
    targets = jnp.roll(tokens, -1, axis=-1)
    mask = jnp.ones(tokens.shape, jnp.float32).at[..., -1].set(0.0)
    return targets, mask


def _logits(cfg, head, embed_p, x):
    x = layers.rms_norm(head["norm"], x, cfg.norm_eps)
    if cfg.tie_embed:
        return layers.unembed(embed_p["table"], x)
    return x @ head["out"]


def make_loss_single(arch: ArchDef):
    cfg = arch.cfg
    plan_remat = True

    def loss(params, batch, rng):
        plan = ReplicatedPlan(cfg, plan_remat)
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = layers.embed(params["embed"], tokens, cfg.embed_scale)
        enc_out = None
        aux_extra = jnp.zeros((), jnp.float32)
        if arch.enc_segments:  # whisper: encode stub frames first
            frames = batch["frames"].astype(x.dtype)
            ex = frames @ params["adapter"]["w"].astype(x.dtype)
            ectx = Ctx(cfg, "train",
                       positions=jnp.arange(frames.shape[1], dtype=jnp.int32))
            ex, eaux, _ = engine.run_segments(
                plan, arch, arch.enc_segments, params["enc_stacks"], None,
                ex, ectx)
            enc_out = ex
            aux_extra = aux_extra + eaux
        n_patch = 0
        if cfg.n_patches:  # vlm: prepend stub patch embeddings
            patches = batch["patches"].astype(x.dtype)
            n_patch = patches.shape[1]
            x = jnp.concatenate([patches, x], axis=1)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        ctx = Ctx(cfg, "train", positions=positions, enc_out=enc_out)
        x, aux, _ = engine.run_segments(
            plan, arch, arch.segments, params["stacks"], None, x, ctx)
        if n_patch:
            x = x[:, n_patch:]
        targets, mask = _targets_and_mask(tokens)
        logits = _logits(cfg, params["head"], params["embed"], x)
        total = layers.softmax_xent(logits, targets, mask) + aux + aux_extra
        if arch.mtp_block is not None:  # deepseek MTP: predict t+2
            e2 = layers.embed(params["embed"], jnp.roll(tokens, -1, axis=-1),
                              cfg.embed_scale)
            h = jnp.concatenate(
                [layers.rms_norm(params["mtp"]["n_x"], x, cfg.norm_eps),
                 layers.rms_norm(params["mtp"]["n_e"], e2, cfg.norm_eps)],
                axis=-1) @ params["mtp"]["proj"].astype(x.dtype)
            h, _, _ = plan.block(arch.mtp_block, params["mtp"]["block"],
                                 None, h, ctx, None)
            logits2 = _logits(cfg, params["head"], params["embed"], h)
            targets2 = jnp.roll(tokens, -2, axis=-1)
            mask2 = jnp.ones(tokens.shape, jnp.float32)
            mask2 = mask2.at[..., -2:].set(0.0)
            total = total + cfg.mtp_loss_weight * layers.softmax_xent(
                logits2, targets2, mask2)
        return total

    return loss


def _mk_shard_resid(topo: Topology):
    """Pin [..., t, d] to the Megatron-SP residual layout (t over
    'model') immediately after row-parallel projections, so SPMD lowers
    the TP reduction as reduce-scatter instead of all-reduce + slice."""
    m = topo.model_shards

    def shard(x):
        t = x.shape[-2]
        if m <= 1 or t % m:
            return x
        spec = P(*([None] * (x.ndim - 2)), "model", None)
        return topo.constrain(x, spec)

    return shard


def _mk_shard_heads(topo: Topology):
    """Pin [..., h, hd] tensors to head-sharded TP layout (divisibility
    guarded); works under vmap (constraint applies to the logical dims)."""
    m = topo.model_shards

    def shard(x):
        h = x.shape[-2]
        if m <= 1 or h % m:
            return x
        spec = P(*([None] * (x.ndim - 2)), "model", None)
        return topo.constrain(x, spec)

    return shard


def make_loss_master(arch: ArchDef, topo: Topology, full_mspecs, per_block,
                     cspecs):
    cfg = arch.cfg
    assert not arch.enc_segments, "enc-dec archs use the replicated regime"
    pd = (topo.pods, topo.devices_per_pod)
    vmap2 = lambda f: jax.vmap(jax.vmap(f))

    def loss_master(params, delta, batch, rngs, lift):
        act_spec = (None if _DISABLE_OPT else
                    P(topo.pod_axis, topo.data_axis, None, "model", None))
        plan = FsdpPlan(cfg, lift, per_block, cspecs, pd, True,
                        topo=topo, act_spec=act_spec)
        tokens = batch["tokens"]                       # [P, D, b, t]
        emb_dev = lift(params["embed"], delta["embed"],
                       full_mspecs["embed"], cspecs["embed"])
        x = vmap2(lambda e, tk: layers.embed(e, tk, cfg.embed_scale))(
            emb_dev, tokens)
        n_patch = 0
        if cfg.n_patches:
            patches = batch["patches"].astype(x.dtype)  # [P,D,b,np,d]
            n_patch = patches.shape[3]
            x = jnp.concatenate([patches, x], axis=3)
        positions = jnp.arange(x.shape[3], dtype=jnp.int32)
        ctx = Ctx(cfg, "train", positions=positions,
                  shard_heads=None if _DISABLE_OPT else
                  _mk_shard_heads(topo),
                  shard_resid=None if _DISABLE_OPT else
                  _mk_shard_resid(topo))
        x, aux, _ = engine.run_segments(
            plan, arch, arch.segments, params["stacks"], delta["stacks"],
            x, ctx)
        if n_patch:
            x = x[:, :, :, n_patch:]
        head_dev = lift(params["head"], delta["head"],
                        full_mspecs["head"], cspecs["head"])
        targets, mask = _targets_and_mask(tokens)
        losses = vmap2(
            lambda h, e, xx, tg, mk: layers.softmax_xent(
                _logits(cfg, h, e, xx), tg, mk))(
            head_dev, emb_dev, x, targets, mask)       # [P, D]
        losses = losses + aux
        if arch.mtp_block is not None:
            mtp_dev = lift(params["mtp"], delta["mtp"],
                           full_mspecs["mtp"], cspecs["mtp"])
            e2 = vmap2(lambda e, tk: layers.embed(e, tk, cfg.embed_scale))(
                emb_dev, jnp.roll(tokens, -1, axis=-1))
            h = vmap2(lambda mp, xx, ee: jnp.concatenate(
                [layers.rms_norm(mp["n_x"], xx, cfg.norm_eps),
                 layers.rms_norm(mp["n_e"], ee, cfg.norm_eps)],
                axis=-1) @ mp["proj"].astype(xx.dtype))(mtp_dev, x, e2)
            bd = arch.mtp_block
            h, _ = vmap2(lambda w, xx: bd.apply(w, xx, ctx, None)[:2])(
                mtp_dev["block"], h)
            targets2 = jnp.roll(tokens, -2, axis=-1)
            mask2 = jnp.ones(tokens.shape, jnp.float32)
            mask2 = mask2.at[..., -2:].set(0.0)
            l2 = vmap2(lambda hd, e, xx, tg, mk: layers.softmax_xent(
                _logits(cfg, hd, e, xx), tg, mk))(
                head_dev, emb_dev, h, targets2, mask2)
            losses = losses + cfg.mtp_loss_weight * l2
        return jnp.sum(losses), losses

    return loss_master


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

class ServeGatherPlan(ReplicatedPlan):
    """Serve-time plan for FSDP-stored params: constrain each layer's
    shards to the compute layout (a per-layer all-gather, no autodiff)."""

    def __init__(self, cfg, topo, blocks, act_spec=None):
        super().__init__(cfg, remat=False)
        self.topo = topo
        self.blocks = blocks
        self.act_spec = act_spec

    def act(self, x):
        if self.topo is None or self.act_spec is None:
            return x
        seq_dim = len(self.act_spec) - 2
        if x.shape[seq_dim] % max(self.topo.model_shards, 1):
            return x
        return self.topo.constrain(x, self.act_spec)

    def block(self, bd, lp, ld, x, ctx, cache):
        lp = jax.tree.map(
            lambda a, s: self.topo.constrain(a, P(*s)), lp, bd.specs,
            is_leaf=lambda v: v is None)
        y, aux, nc = bd.apply(lp, x, ctx, cache)
        return self.act(y), aux, nc


def make_cache(arch: ArchDef, b: int, max_len: int, dtype=jnp.bfloat16):
    occ = occurrence_counts(arch.segments)
    stacks = {}
    for name, n in occ.items():
        bd = arch.blocks[name]
        if bd.cache_init is None:
            continue
        slice0 = jax.eval_shape(lambda: bd.cache_init(b, max_len, dtype))
        stacks[name] = jax.tree.map(
            lambda a: jnp.zeros((n,) + a.shape, a.dtype), slice0)
    return {"stacks": stacks, "pos": jnp.zeros((), jnp.int32)}


def cache_specs(arch: ArchDef, batch_ax, len_axis=None):
    occ = occurrence_counts(arch.segments)
    stacks = {}
    for name in occ:
        bd = arch.blocks[name]
        if bd.cache_specs is None:
            continue
        stacks[name] = engine._prepend(bd.cache_specs(batch_ax, len_axis),
                                       None)
    return {"stacks": stacks, "pos": P()}


SERVE_RESIDENT_BUDGET = 12e9   # bf16 bytes/chip below which weights stay
                               # resident in compute layout (no per-layer
                               # gathers at decode)


def serve_layout(cfg: LMConfig, topo: Topology, n_params: int) -> str:
    """'resident' (compute layout) | 'gather' (FSDP layout + per-layer
    all-gather).  Beyond-paper optimization, EXPERIMENTS.md Sec. Perf."""
    if cfg.param_mode != "fsdp":
        return "resident"
    if _DISABLE_OPT:
        return "gather"
    per_chip = 2.0 * n_params / max(topo.model_shards, 1)
    return "resident" if per_chip <= SERVE_RESIDENT_BUDGET else "gather"


def make_serve_fns(arch: ArchDef, topo: Topology, layout: str = "gather"):
    cfg = arch.cfg
    fsdp = cfg.param_mode == "fsdp" and layout == "gather"

    def mk_plan(batch: int = 0):
        if fsdp:
            ba = None
            if batch > 1:
                axes = tuple(a for a in (topo.pod_axis, topo.data_axis)
                             if a)
                ba = axes if len(axes) > 1 else axes[0]
            act_spec = P(ba, "model", None)
            return ServeGatherPlan(cfg, topo, arch.blocks,
                                   act_spec=act_spec)
        return ReplicatedPlan(cfg, remat=False)

    def embed_in(params, tokens):
        e = params["embed"]
        if fsdp:
            e = jax.tree.map(lambda a, s: topo.constrain(a, P(*s)),
                             e, layers.embed_specs(cfg.vocab,
                                                   topo.model_shards))
        return layers.embed(e, tokens, cfg.embed_scale), e

    def head_out(params, e, x):
        h = params["head"]
        if fsdp:
            ok = cfg.vocab % max(topo.model_shards, 1) == 0
            hs = {"norm": P(None)}
            if not cfg.tie_embed:
                hs["out"] = P(None, "model" if ok else None)
            h = jax.tree.map(lambda a, s: topo.constrain(a, P(*s)), h, hs)
        return _logits(cfg, h, e, x)

    def prefill(params, batch, max_len):
        """Process the full prompt; returns (last-token logits, cache)."""
        plan = mk_plan(batch["tokens"].shape[0])
        tokens = batch["tokens"]
        b, t = tokens.shape
        x, e = embed_in(params, tokens)
        enc_out = None
        if arch.enc_segments:
            frames = batch["frames"].astype(x.dtype)
            ex = frames @ params["adapter"]["w"].astype(x.dtype)
            ectx = Ctx(cfg, "train",
                       positions=jnp.arange(frames.shape[1],
                                            dtype=jnp.int32))
            ex, _, _ = engine.run_segments(
                plan, arch, arch.enc_segments, params["enc_stacks"], None,
                ex, ectx)
            enc_out = ex
        n_patch = 0
        if cfg.n_patches:
            patches = batch["patches"].astype(x.dtype)
            n_patch = patches.shape[1]
            x = jnp.concatenate([patches, x], axis=1)
        cache = make_cache(arch, b, max_len,
                           jnp.bfloat16)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        ctx = Ctx(cfg, "prefill", positions=positions,
                  pos=jnp.zeros((), jnp.int32), enc_out=enc_out,
                  shard_heads=_mk_shard_heads(topo) if fsdp else None)
        x, _, new_stacks = engine.run_segments(
            plan, arch, arch.segments, params["stacks"], None, x, ctx,
            caches=cache["stacks"])
        logits = head_out(params, e, x[:, -1:])
        return logits, {"stacks": new_stacks,
                        "pos": jnp.full((), x.shape[1], jnp.int32)}

    def decode_step(params, cache, tokens):
        """One decode step: tokens [b, 1] -> (logits [b, 1, V], cache')."""
        plan = mk_plan(tokens.shape[0])
        pos = cache["pos"]
        x, e = embed_in(params, tokens)
        positions = pos + jnp.arange(tokens.shape[1], dtype=jnp.int32)
        ctx = Ctx(cfg, "decode", positions=positions, pos=pos)
        x, _, new_stacks = engine.run_segments(
            plan, arch, arch.segments, params["stacks"], None, x, ctx,
            caches=cache["stacks"])
        logits = head_out(params, e, x)
        return logits, {"stacks": new_stacks, "pos": pos + tokens.shape[1]}

    return prefill, decode_step


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BuiltModel:
    cfg: LMConfig
    arch: ArchDef
    topo: Topology
    bundle: hier.ModelBundle
    init_params: Callable
    abstract_params: Callable
    prefill: Callable
    decode_step: Callable
    make_cache: Callable
    cache_specs: Callable
    serve_layout: str = "resident"


def build_model(cfg: LMConfig, topo: Topology) -> BuiltModel:
    arch = make_archdef(cfg, topo.model_shards)
    cspecs = compute_specs(arch, topo.model_shards)
    init_fn = functools.partial(init_params, arch)
    shapes = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    fsdp = cfg.param_mode == "fsdp"
    mspecs, per_block = build_master_specs(arch, cspecs, shapes, topo, fsdp)
    bundle = hier.ModelBundle(
        loss=None if fsdp else make_loss_single(arch),
        compute_specs=cspecs,
        master_specs=mspecs,
        loss_master=(make_loss_master(arch, topo, mspecs, per_block, cspecs)
                     if fsdp else None),
        param_mode=cfg.param_mode)
    import math
    n_params = sum(math.prod(a.shape) for a in jax.tree.leaves(shapes))
    slayout = serve_layout(cfg, topo, n_params)
    prefill, decode_step = make_serve_fns(arch, topo, slayout)
    return BuiltModel(
        cfg=cfg, arch=arch, topo=topo, bundle=bundle,
        init_params=init_fn, abstract_params=lambda: shapes,
        prefill=prefill, decode_step=decode_step,
        make_cache=functools.partial(make_cache, arch),
        cache_specs=functools.partial(cache_specs, arch),
        serve_layout=slayout)
