"""Property suite for the drift-correction method axis of the ``ref_fed``
oracle: SCAFFOLD control variates + MTGC multi-timescale correction.

The oracle is the ground truth for ``scaffold_hier_signsgd`` and
``mtgc_hier_signsgd``, so their semantics are pinned here *independently*
of the distributed implementation:

  * zero inter-cluster heterogeneity (every client holds the same data)
    makes every pre-sign correction EXACTLY zero, so all three corrected
    methods reproduce the plain ``hier_signsgd`` trajectory bitwise;
  * SCAFFOLD's bookkeeping telescopes: after any number of rounds under
    full participation, c_global equals the share-weighted sum of the
    final per-client c_local states (each round's drift increment is
    sum ew*sh*(c_local_new - c_local_old), and the sum collapses);
  * an all-abstaining round leaves EVERY piece of correction state (and
    the model) untouched -- the EF-style carry-forward contract,
    including the mtgc cloud-timescale eta term on a cloud-period round;
  * full-participation unit-weight cells are invariant under permuting
    the clients of an edge (state permutes with them, w is unchanged).

All trajectories run on a dyadic grid (targets on 2^-4, mu = 2^-6,
rho = 1, uniform shares over 2 or 4 clients / 1 or 2 edges) so every
weighted sum is EXACT in float32 and the properties hold bitwise, not
just approximately.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import ref_fed

DIM = 8
GRID = 2.0 ** -4          # targets live on this dyadic grid
MU = 2.0 ** -6            # so do all step sizes / shares -> exact sums

CORR_METHODS = list(ref_fed.CLIENT_CORRECTION_METHODS)


def _grad_fn(targets):
    """Deterministic linear grads g_qk = w - target_qk (rng unused)."""
    def grad_fn(params, batch, rng):
        return {"w": params["w"] - targets[batch["q"]][batch["k"]]}
    return grad_fn


def _targets(q_edges, n, seed, homogeneous=False):
    rng = np.random.default_rng(seed)
    t = rng.integers(-32, 33, size=(q_edges, n, DIM)).astype(np.float32)
    if homogeneous:
        t[:] = t[0, 0]
    return jnp.asarray(t * GRID)


def _round(state, method, targets, order=None, mask=None, vote_w=None,
           reweight=False, cloud_period=2, t_e=2):
    """One oracle round; clients of edge q run in ``order`` (default
    identity), uniform dyadic shares, uniform edge weights."""
    q_edges, n = targets.shape[0], targets.shape[1]
    order = list(range(n)) if order is None else list(order)
    cfg = ref_fed.HierConfig(mu=MU, t_e=t_e, rho=1.0, method=method,
                             cloud_period=cloud_period)
    batches = [[[{"q": q, "k": int(k)}] * t_e for k in order]
               for q in range(q_edges)]
    anchors = [[{"q": q, "k": int(k)} for k in order]
               for q in range(q_edges)]
    return ref_fed.global_round(
        state, cfg, _grad_fn(targets), batches, anchors,
        [1.0 / q_edges] * q_edges, [[1.0 / n] * n] * q_edges,
        jax.random.PRNGKey(0),
        device_mask=None if mask is None else [list(mask)] * q_edges,
        vote_weights=None if vote_w is None else [list(vote_w)] * q_edges,
        reweight_participation=reweight)


def _w(state):
    return np.asarray(state.w["w"])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 7), st.sampled_from([2, 4]), st.sampled_from([1, 2]),
       st.sampled_from(CORR_METHODS + ["dc_hier_signsgd"]))
def test_zero_heterogeneity_matches_plain_trajectory(seed, n, q_edges,
                                                     method):
    """Identical data everywhere -> every correction is exactly zero
    (dyadic arithmetic) -> the corrected trajectory IS the plain
    hier_signsgd trajectory, bitwise, round after round."""
    targets = _targets(q_edges, n, seed, homogeneous=True)
    plain = corrected = ref_fed.init_state({"w": jnp.zeros(DIM)}, q_edges)
    for _ in range(3):
        plain = _round(plain, "hier_signsgd", targets)
        corrected = _round(corrected, method, targets)
        np.testing.assert_array_equal(_w(plain), _w(corrected))
    if method == "mtgc_hier_signsgd":
        for q in range(q_edges):
            np.testing.assert_array_equal(
                np.asarray(corrected.corr_edge[q]["w"]), 0.0)
            for k in range(n):
                np.testing.assert_array_equal(
                    np.asarray(corrected.corr_cl[q][k]["w"]), 0.0)
    elif method == "scaffold_hier_signsgd":
        for q in range(q_edges):       # effective term c_global - c_local
            for k in range(n):
                np.testing.assert_array_equal(
                    np.asarray(corrected.corr_edge[q]["w"]),
                    np.asarray(corrected.corr_cl[q][k]["w"]))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 7), st.sampled_from([2, 4]), st.sampled_from([1, 2]),
       st.integers(1, 4))
def test_scaffold_bookkeeping_telescopes(seed, n, q_edges, rounds):
    """Under full participation each round's c_global increment is the
    share-weighted sum of the c_local updates, so after R rounds
    c_global == sum_q ew_q sum_k sh_qk c_local_qk -- exactly, on the
    dyadic grid (and every edge holds the identical c_global copy)."""
    targets = _targets(q_edges, n, seed)
    state = ref_fed.init_state({"w": jnp.zeros(DIM)}, q_edges)
    for _ in range(rounds):
        state = _round(state, "scaffold_hier_signsgd", targets)
    expect = np.zeros(DIM, np.float32)
    for q in range(q_edges):
        for k in range(n):
            expect += (1.0 / q_edges) * (1.0 / n) * np.asarray(
                state.corr_cl[q][k]["w"])
    for q in range(q_edges):
        np.testing.assert_array_equal(np.asarray(state.corr_edge[q]["w"]),
                                      expect)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 7), st.sampled_from([2, 4]),
       st.sampled_from(CORR_METHODS))
def test_all_abstaining_round_is_identity(seed, n, method):
    """EF carry-forward contract: a round in which every client abstains
    updates NOTHING -- model, c_local/gamma, c_global/eta all bitwise
    unchanged.  cloud_period=1 forces the mtgc eta refresh to be
    *attempted* (and gated) on the abstaining round too."""
    targets = _targets(2, n, seed)
    state = ref_fed.init_state({"w": jnp.zeros(DIM)}, 2)
    state = _round(state, method, targets, mask=[True] * n,
                   vote_w=[1] * n, reweight=True, cloud_period=1)
    after = _round(state, method, targets, mask=[False] * n,
                   vote_w=[1] * n, reweight=True, cloud_period=1)
    np.testing.assert_array_equal(_w(state), _w(after))
    for q in range(2):
        np.testing.assert_array_equal(np.asarray(state.corr_edge[q]["w"]),
                                      np.asarray(after.corr_edge[q]["w"]))
        for k in range(n):
            np.testing.assert_array_equal(
                np.asarray(state.corr_cl[q][k]["w"]),
                np.asarray(after.corr_cl[q][k]["w"]))
    assert after.round == state.round + 1


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 7), st.sampled_from([2, 4]), st.sampled_from([1, 2]),
       st.sampled_from(CORR_METHODS))
def test_full_participation_invariant_to_client_permutation(seed, n,
                                                            q_edges,
                                                            method):
    """Full-participation unit-weight cells: permuting the clients of
    every edge permutes the per-client correction state with them and
    leaves the model trajectory bitwise unchanged (uniform dyadic
    shares make the weighted sums exactly commutative)."""
    rng = np.random.default_rng(seed + 100)
    perm = [int(i) for i in rng.permutation(n)]
    targets = _targets(q_edges, n, seed)

    def run(order):
        state = ref_fed.init_state({"w": jnp.zeros(DIM)}, q_edges)
        for _ in range(2):
            state = _round(state, method, targets, order=order,
                           mask=[True] * n, vote_w=[1] * n, reweight=True)
        return state

    ident, permuted = run(range(n)), run(perm)
    np.testing.assert_array_equal(_w(ident), _w(permuted))
    for q in range(q_edges):
        np.testing.assert_array_equal(
            np.asarray(ident.corr_edge[q]["w"]),
            np.asarray(permuted.corr_edge[q]["w"]))
        for j, k in enumerate(perm):
            # slot j of the permuted run hosts client perm[j]
            np.testing.assert_array_equal(
                np.asarray(ident.corr_cl[q][k]["w"]),
                np.asarray(permuted.corr_cl[q][j]["w"]))
