"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls these.

Mesh shapes (TPU v5e pods):
  single-pod:  (16, 16)    axes ("data", "model")          = 256 chips
  multi-pod:   (2, 16, 16) axes ("pod", "data", "model")   = 512 chips

The paper's hierarchy binds to these axes: ``data`` = devices within an
edge cluster (1-bit vote tier), ``pod`` = edge servers under the cloud
(model-average tier).  On a single pod the cloud tier degenerates to Q=1
(the pod axis is absent and the paper's delta is identically zero).

The ``model`` axis is tensor parallelism, orthogonal to the hierarchy:
with ``state_layout="flat"`` the flat master buffer is laid out as one
bucket per model shard (``core.flatbuf`` sharded layouts) and the fused
transport runs as a shard_map program over this mesh, so the 16-way
model axis of the production shapes never gathers a leaf -- see
docs/architecture.md.
"""
from __future__ import annotations

import jax

from repro.core.topology import Topology


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_topology(*, multi_pod: bool = False) -> Topology:
    return Topology(mesh=make_production_mesh(multi_pod=multi_pod),
                    pod_axis="pod" if multi_pod else None)


def make_host_topology(pods: int = 1, data: int = 1, model: int = 1
                       ) -> Topology:
    """Small host-device mesh for tests (requires forced device count)."""
    import numpy as np
    devs = np.array(jax.devices()[: pods * data * model])
    if pods > 1:
        mesh = jax.sharding.Mesh(devs.reshape(pods, data, model),
                                 ("pod", "data", "model"))
        return Topology(mesh=mesh, pod_axis="pod")
    mesh = jax.sharding.Mesh(devs.reshape(data, model), ("data", "model"))
    return Topology(mesh=mesh, pod_axis=None)
