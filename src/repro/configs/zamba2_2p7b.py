"""zamba2-2.7b [hybrid]: 54 Mamba2 blocks d2560 (ssm_state=64) + ONE
shared transformer block (32H, ff10240) applied every 6 blocks with tied
weights. [arXiv:2411.15242; hf]
"""
import dataclasses

from repro.models.config import LMConfig, SSMCfg

CONFIG = LMConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, head_dim=80, rope_theta=1e4,
    ssm=SSMCfg(d_state=64, d_conv=4, expand=2, n_groups=1, chunk=256,
               attn_every=6),
    param_mode="replicated", supports_long_context=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="zamba2-smoke", n_layers=6, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2, n_groups=1, chunk=16,
               attn_every=3),
)
