"""Chaos-cell report: run the churn parity cells and emit a JSON
artifact (reports/chaos_cells.json) for the nightly chaos tier.

Each cell runs the deterministic churn schedule (client kill, straggler
demotion, heartbeat loss, fail-open window, recoveries -- the same
``chaos_injector`` schedule the parity matrix pins) through the jitted
hierarchical step and compares the cloud-aggregated model against the
``ref_fed`` oracle driven by the SAME compiled membership arrays:

  * method cells   -- plain/dc/scaffold/mtgc sign cells must be EXACT
                      (bitwise); hier_sgd within float tolerance;
  * transport cells -- every transport x layout x client-mode must be
                      bitwise the reference cell;
  * replay cell    -- nan-loss -> checkpoint restore -> replay must be
                      bitwise the uninterrupted trajectory.

Exit status is nonzero if any cell misses its contract, so the nightly
job both uploads the artifact and fails loudly.

  PYTHONPATH=src python benchmarks/chaos_report.py [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                       / "tests" / "helpers"))

import numpy as np

import parity_harness as H
from repro.core.topology import single_device_topology

REPORT = (pathlib.Path(__file__).resolve().parents[1] / "reports"
          / "chaos_cells.json")

SIGN_METHODS = ("hier_signsgd", "dc_hier_signsgd",
                "scaffold_hier_signsgd", "mtgc_hier_signsgd")


def max_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(a[k], np.float64)
                                   - np.asarray(b[k], np.float64))))
               for k in a)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(REPORT))
    args = ap.parse_args()

    topo = single_device_topology()
    problem = H.make_problem(1, 1)
    cc = H.client_cfg(1, 1, 2, "full")
    inj = H.chaos_injector(1, 1, 2, problem["t_e"])
    arrays = H.chaos_arrays(problem, cc, inj)
    cells, ok = [], True

    def record(name, want_exact, diff, wall, extra=None):
        nonlocal ok
        passed = diff == 0.0 if want_exact else diff < 1e-5
        ok &= passed
        cells.append({"cell": name, "exact": diff == 0.0,
                      "max_abs_diff": diff, "passed": passed,
                      "wall_s": round(wall, 1), **(extra or {})})
        print(f"{'PASS' if passed else 'FAIL'} {name:42s} "
              f"diff={diff:.2e} ({wall:.1f}s)")

    # method cells vs the grown oracle
    ref_dc = None
    for method in SIGN_METHODS + ("hier_sgd",):
        t0 = time.time()
        ref, _ = H.run_hier_chaos(topo, problem, method, clients=cc,
                                  arrays=arrays)
        if method == "dc_hier_signsgd":
            ref_dc = ref
        oracle = H.run_oracle_chaos(problem, method, cc, arrays)
        diff = max_diff(H.aggregate(ref, arrays[-1].edge_weights), oracle)
        record(f"oracle/{method}", method != "hier_sgd", diff,
               time.time() - t0)

    # transport x layout x mode cells, bitwise vs the dc reference
    for transport in H.SIGN_TRANSPORTS:
        for layout in H.LAYOUTS:
            for mode in ("merged", "stream"):
                t0 = time.time()
                ccm = (cc if mode == "merged"
                       else dataclasses.replace(cc, mode="stream"))
                got, _ = H.run_hier_chaos(topo, problem,
                                          "dc_hier_signsgd", transport,
                                          layout, clients=ccm,
                                          arrays=arrays)
                record(f"cross/{transport}/{layout}/{mode}", True,
                       max_diff(ref_dc, got), time.time() - t0)

    # kill-restore-replay: nan event + checkpoint restore, bitwise
    t0 = time.time()
    inj_n = H.chaos_injector(1, 1, 2, problem["t_e"], nan_step=5)
    with tempfile.TemporaryDirectory() as d:
        got, _ = H.run_hier_chaos(topo, problem, "dc_hier_signsgd",
                                  clients=cc, injector=inj_n,
                                  arrays=arrays, ckpt_dir=d,
                                  ckpt_every=problem["t_e"])
    record("kill-restore-replay/dc_hier_signsgd", True,
           max_diff(ref_dc, got), time.time() - t0)

    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(
        {"schedule_events": len(inj.events), "cells": cells,
         "all_passed": ok}, indent=1))
    print(f"{len(cells)} chaos cells -> {out}")
    if not ok:
        raise SystemExit("chaos cells FAILED")


if __name__ == "__main__":
    main()
