"""gemma3-1b [dense]: 26L d1152 4H (kv=1, MQA) ff6912 v262144; 5:1
local:global (window 1024), tied embeddings, qk-norm.
[hf:google/gemma-3-1b-pt; unverified]
"""
import dataclasses

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab=262144, head_dim=288,
    window=1024, local_global=(5, 1), qk_norm=True,
    rope_theta=1e4, rope_theta_global=1e6,
    tie_embed=True, embed_scale=True, act="gelu",
    param_mode="replicated", supports_long_context=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma3-1b-smoke", n_layers=8, d_model=64, n_heads=4,
    n_kv_heads=1, d_ff=128, vocab=256, head_dim=16, window=8,
)
