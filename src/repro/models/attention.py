"""Attention blocks: GQA (+ sliding window, qk-norm), MLA, cross-attention.

Single-replica code ([b, t, d] activations).  Decode uses an explicit KV
cache pytree; for ``long_500k`` the cache's *length* dim is sharded over
``data`` (flash-decoding for free: GSPMD splits the softmax reductions
across the cache shards).  MLA decode uses the absorbed formulation so the
per-step cost scales with the 576-dim latent cache, not with H recomputed
keys (DESIGN.md Sec. 4).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.layers import he_init, rope

NEG_INF = -2.0**30


def _heads_spec(n_heads: int, model_shards: int):
    return "model" if (model_shards and n_heads % model_shards == 0) else None


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(rng, cfg):
    d, hd, h, hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 5)
    p = {
        "wq": he_init(ks[0], (d, h * hd)).reshape(d, h, hd),
        "wk": he_init(ks[1], (d, hkv * hd)).reshape(d, hkv, hd),
        "wv": he_init(ks[2], (d, hkv * hd)).reshape(d, hkv, hd),
        "wo": he_init(ks[3], (h * hd, d), h * hd).reshape(h, hd, d),
    }
    if cfg.qk_norm:
        p["qn"] = layers.init_rms(ks[4], hd)
        p["kn"] = layers.init_rms(ks[4], hd)
    return p


def gqa_specs(cfg, model_shards):
    hs = _heads_spec(cfg.n_heads, model_shards)
    hks = _heads_spec(cfg.n_kv_heads, model_shards)
    s = {"wq": P(None, hs, None), "wk": P(None, hks, None),
         "wv": P(None, hks, None), "wo": P(hs, None, None)}
    if cfg.qk_norm:
        s["qn"] = P(None)
        s["kn"] = P(None)
    return s


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=-2)


def _attend(q, k, v, mask):
    """q: [b,tq,h,hd]; k,v: [b,tk,h,hd]; mask: [b?,tq,tk] bool or None."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(q.shape[-1])
    if mask is not None:
        scores = jnp.where(mask[:, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


Q_CHUNK = 1024  # query-block size for the exact chunked path


def attend_causal(q, k, v, window=0, mask_extra=None, q_chunk=Q_CHUNK):
    """Exact causal (optionally sliding-window) attention, q-block chunked.

    Never materializes the full [t, t] score matrix: a lax.scan walks query
    blocks; for window layers only the (window + q_chunk) keys a block can
    see are sliced in, so local-attention FLOPs/bytes scale with the window
    rather than the sequence (this is what makes the gemma3 long-context
    cells sub-quadratic; DESIGN.md Sec. 4).
    """
    b, t, h, hd = q.shape
    if t <= q_chunk or t % q_chunk != 0 or mask_extra is not None:
        mask = causal_mask(t, t, 0, window)[None]
        if mask_extra is not None:
            mask = mask & mask_extra
        return _attend(q, k, v, mask)

    n_blocks = t // q_chunk
    use_window = bool(window) and (window + q_chunk) <= t

    def block(carry, i):
        qs = i * q_chunk
        qb = jax.lax.dynamic_slice_in_dim(q, qs, q_chunk, axis=1)
        if use_window:
            ks = jnp.maximum(qs - window, 0)
            kb = jax.lax.dynamic_slice_in_dim(k, ks, window + q_chunk, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, ks, window + q_chunk, 1)
            kj = ks + jnp.arange(window + q_chunk)[None, :]
        else:
            kb, vb = k, v
            kj = jnp.arange(t)[None, :]
        qi = qs + jnp.arange(q_chunk)[:, None]
        m = kj <= qi
        if window:
            m &= kj > qi - window
        return carry, _attend(qb, kb, vb, m[None])

    _, blocks = jax.lax.scan(block, (), jnp.arange(n_blocks))
    # output head dim follows v (MLA: v_head_dim != qk head dim)
    return jnp.moveaxis(blocks, 0, 1).reshape(b, t, h, v.shape[-1])


def causal_mask(tq, tk, offset=0, window=0):
    """[tq, tk] bool; query i attends key j iff j <= i+offset (& in window)."""
    qi = jnp.arange(tq)[:, None] + offset
    kj = jnp.arange(tk)[None, :]
    m = kj <= qi
    if window:
        m &= kj > qi - window
    return m


def gqa_attn(p, x, positions, cfg, *, theta, window=0, mask_extra=None,
             cache=None, pos=None, prefill=False, cache_spec=None,
             topo=None, shard_heads=None):
    """Returns (out [b,t,d], new_cache).

    Modes: cache=None -> train (full causal, no cache);
    prefill=True -> full causal over the fresh tokens + cache fill at
    offset 0 (exact, since the cache is empty at prefill);
    else decode -> write t tokens at offset ``pos``, attend over cache.
    """
    b, t, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if cfg.qk_norm:
        q = layers.rms_norm(p["qn"], q, cfg.norm_eps)
        k = layers.rms_norm(p["kn"], k, cfg.norm_eps)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    if shard_heads is not None:
        # pin the Megatron layout: q heads sharded over 'model', the
        # sequence gathered at the attention boundary.  Without this,
        # sequence-sharded residuals (SP) make XLA partition the softmax
        # contraction over t and all-reduce f32 attention outputs per
        # q-chunk per layer (EXPERIMENTS.md Sec. Perf, iteration 2).
        q = shard_heads(q)
        k = shard_heads(k)
        v = shard_heads(v)

    if cache is None:
        out = attend_causal(q, _repeat_kv(k, h // hkv),
                            _repeat_kv(v, h // hkv), window, mask_extra)
        new_cache = None
    elif prefill:
        out = attend_causal(q, _repeat_kv(k, h // hkv),
                            _repeat_kv(v, h // hkv), window, mask_extra)
        if cache["k"].shape[1] == window:   # rolled window cache
            ck = jnp.concatenate([cache["k"], k.astype(cache["k"].dtype)],
                                 axis=1)[:, -window:]
            cv = jnp.concatenate([cache["v"], v.astype(cache["v"].dtype)],
                                 axis=1)[:, -window:]
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        new_cache = {"k": ck, "v": cv}
    elif window and cache["k"].shape[1] == window:
        # rolled window cache (local layers at long context): slot W-1 is
        # the newest token; roll left by t and append.
        ck = jnp.concatenate(
            [cache["k"][:, t:], k.astype(cache["k"].dtype)], axis=1)
        cv = jnp.concatenate(
            [cache["v"][:, t:], v.astype(cache["v"].dtype)], axis=1)
        if cache_spec is not None and topo is not None:
            ck = topo.constrain(ck, cache_spec)
            cv = topo.constrain(cv, cache_spec)
        slot = jnp.arange(window)[None, :]
        valid = slot >= (window - 1 - pos)      # global pos >= 0
        mask = jnp.broadcast_to(valid, (t, window))[None]
        out = _attend(q, _repeat_kv(ck, h // hkv),
                      _repeat_kv(cv, h // hkv), mask)
        new_cache = {"k": ck, "v": cv}
    else:
        # decode: write (k, v) at offset ``pos``, attend over the cache.
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        if cache_spec is not None and topo is not None:
            ck = topo.constrain(ck, cache_spec)
            cv = topo.constrain(cv, cache_spec)
        lk = ck.shape[1]
        kj = jnp.arange(lk)[None, :]
        valid = kj <= pos
        if window:
            valid &= kj > pos - window
        mask = jnp.broadcast_to(valid, (t, lk))[None]
        out = _attend(q, _repeat_kv(ck, h // hkv),
                      _repeat_kv(cv, h // hkv), mask)
        new_cache = {"k": ck, "v": cv}
    out = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return out, new_cache


def gqa_cache_init(cfg, b, max_len, dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((b, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((b, max_len, cfg.n_kv_heads, cfg.hd), dtype),
    }


def gqa_cache_specs(cfg, model_shards, batch_axes, len_axis=None):
    """batch_axes: spec entry for the batch dim; len_axis: 'data' shards the
    cache length (long-context flash-decoding layout)."""
    hks = _heads_spec(cfg.n_kv_heads, model_shards)
    return {"k": P(batch_axes, len_axis, hks, None),
            "v": P(batch_axes, len_axis, hks, None)}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn(p, x, enc_kv, cfg):
    """enc_kv: {"k","v": [b, frames, hkv, hd]} precomputed at prefill."""
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    out = _attend(q, _repeat_kv(enc_kv["k"].astype(q.dtype), h // hkv),
                  _repeat_kv(enc_kv["v"].astype(q.dtype), h // hkv), None)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


def init_cross(rng, cfg):
    d, hd, h, hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 4)
    return {
        "wq": he_init(ks[0], (d, h * hd)).reshape(d, h, hd),
        "wk": he_init(ks[1], (d, hkv * hd)).reshape(d, hkv, hd),
        "wv": he_init(ks[2], (d, hkv * hd)).reshape(d, hkv, hd),
        "wo": he_init(ks[3], (h * hd, d), h * hd).reshape(h, hd, d),
    }


def cross_specs(cfg, model_shards):
    return {k: v for k, v in gqa_specs(
        dataclasses_replace_qknorm(cfg), model_shards).items()
        if k in ("wq", "wk", "wv", "wo")}


def dataclasses_replace_qknorm(cfg):
    import dataclasses
    return dataclasses.replace(cfg, qk_norm=False)


def cross_kv(p, enc_out, cfg):
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"])
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(rng, cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(rng, 8)
    return {
        "wdq": he_init(ks[0], (d, m.q_lora_rank)),
        "qn": layers.init_rms(ks[1], m.q_lora_rank),
        "wuq": he_init(ks[1], (m.q_lora_rank, h * qk),
                       m.q_lora_rank).reshape(m.q_lora_rank, h, qk),
        "wdkv": he_init(ks[2], (d, m.kv_lora_rank)),
        "kvn": layers.init_rms(ks[3], m.kv_lora_rank),
        "wkr": he_init(ks[3], (d, m.qk_rope_head_dim)),
        "wuk": he_init(ks[4], (m.kv_lora_rank, h * m.qk_nope_head_dim),
                       m.kv_lora_rank).reshape(
                           m.kv_lora_rank, h, m.qk_nope_head_dim),
        "wuv": he_init(ks[5], (m.kv_lora_rank, h * m.v_head_dim),
                       m.kv_lora_rank).reshape(
                           m.kv_lora_rank, h, m.v_head_dim),
        "wo": he_init(ks[6], (h * m.v_head_dim, d),
                      h * m.v_head_dim).reshape(h, m.v_head_dim, d),
    }


def mla_specs(cfg, model_shards):
    hs = _heads_spec(cfg.n_heads, model_shards)
    return {
        "wdq": P(None, None), "qn": P(None),
        "wuq": P(None, hs, None),
        "wdkv": P(None, None), "kvn": P(None), "wkr": P(None, None),
        "wuk": P(None, hs, None), "wuv": P(None, hs, None),
        "wo": P(hs, None, None),
    }


def mla_attn(p, x, positions, cfg, *, cache=None, pos=None, prefill=False,
             cache_spec=None, topo=None, shard_heads=None):
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.n_heads
    nope, rdim = m.qk_nope_head_dim, m.qk_rope_head_dim
    # queries
    ql = layers.rms_norm(p["qn"], x @ p["wdq"], cfg.norm_eps)
    q = jnp.einsum("btr,rhk->bthk", ql, p["wuq"])
    if shard_heads is not None:
        q = shard_heads(q)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    # latent kv
    ckv = layers.rms_norm(p["kvn"], x @ p["wdkv"], cfg.norm_eps)  # [b,t,r]
    k_rope = rope((x @ p["wkr"])[:, :, None, :], positions,
                  cfg.rope_theta)[:, :, 0]                         # [b,t,rdim]

    if cache is None or prefill:
        k_nope = jnp.einsum("btr,rhk->bthk", ckv, p["wuk"])
        v = jnp.einsum("btr,rhk->bthk", ckv, p["wuv"])
        if shard_heads is not None:
            k_nope = shard_heads(k_nope)
            v = shard_heads(v)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                      (b, t, h, rdim))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = attend_causal(qf, k, v)
        if prefill:
            cc = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0))
            cr = jax.lax.dynamic_update_slice(
                cache["kr"], k_rope.astype(cache["kr"].dtype), (0, 0, 0))
            new_cache = {"ckv": cc, "kr": cr}
        else:
            new_cache = None
    else:
        # absorbed decode: score = q_nope . (W_uk c) + q_rope . k_rope
        #                        = (q_nope W_uk^T) . c + q_rope . k_rope
        cc = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, pos, 0))
        cr = jax.lax.dynamic_update_slice(
            cache["kr"], k_rope.astype(cache["kr"].dtype), (0, pos, 0))
        if cache_spec is not None and topo is not None:
            cc = topo.constrain(cc, cache_spec["ckv"])
            cr = topo.constrain(cr, cache_spec["kr"])
        q_abs = jnp.einsum("bthk,rhk->bthr", q_nope, p["wuk"])    # [b,t,h,r]
        scores = (jnp.einsum("bthr,bsr->bhts", q_abs, cc.astype(q_abs.dtype))
                  + jnp.einsum("bthk,bsk->bhts", q_rope,
                               cr.astype(q_rope.dtype))).astype(jnp.float32)
        scores = scores / math.sqrt(nope + rdim)
        lk = cc.shape[1]
        valid = jnp.arange(lk)[None, :] <= pos
        scores = jnp.where(valid[:, None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o_lat = jnp.einsum("bhts,bsr->bthr", w, cc.astype(x.dtype))
        out = jnp.einsum("bthr,rhk->bthk", o_lat, p["wuv"])
        new_cache = {"ckv": cc, "kr": cr}
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return y, new_cache


def mla_cache_init(cfg, b, max_len, dtype=jnp.bfloat16):
    m = cfg.mla
    return {"ckv": jnp.zeros((b, max_len, m.kv_lora_rank), dtype),
            "kr": jnp.zeros((b, max_len, m.qk_rope_head_dim), dtype)}


def mla_cache_specs(cfg, model_shards, batch_axes, len_axis=None):
    return {"ckv": P(batch_axes, len_axis, None),
            "kr": P(batch_axes, len_axis, None)}
