"""Mixture-of-Experts block (GShard-style capacity dispatch, EP over 'model').

Baseline dispatch is the classic one-hot einsum (the standard JAX MoE
lowering; its dispatch FLOPs are honestly charged to the roofline).  The
``gather`` dispatch replaces the einsums with take/segment-sum index ops
(bytes instead of FLOPs) -- a beyond-paper perf knob evaluated in
EXPERIMENTS.md Sec. Perf.

Expert weights are sharded over the ``model`` axis (expert parallelism);
GSPMD inserts the token all-to-all at the dispatch/combine boundaries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.layers import he_init


def _experts_spec(n_experts, model_shards):
    return "model" if (model_shards and n_experts % model_shards == 0) else None


def init_moe(rng, cfg):
    e = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(rng, 6)
    p = {
        "router": he_init(ks[0], (d, e.n_experts)),
        "w_gate": he_init(ks[1], (e.n_experts, d, e.d_expert)),
        "w_up": he_init(ks[2], (e.n_experts, d, e.d_expert)),
        "w_down": he_init(ks[3], (e.n_experts, e.d_expert, d), e.d_expert),
    }
    if e.n_shared:
        p["shared"] = layers.init_mlp(ks[4], d, e.n_shared * e.d_expert)
    if e.dense_residual_ff:
        p["dense"] = layers.init_mlp(ks[5], d, e.dense_residual_ff)
    return p


def moe_specs(cfg, model_shards):
    e = cfg.moe
    es = _experts_spec(e.n_experts, model_shards)
    s = {
        "router": P(None, None),
        "w_gate": P(es, None, None),
        "w_up": P(es, None, None),
        "w_down": P(es, None, None),
    }
    if e.n_shared:
        s["shared"] = layers.mlp_specs("swiglu")
    if e.dense_residual_ff:
        s["dense"] = layers.mlp_specs("swiglu")
    return s


def _route(p, xg, e):
    """xg: [G, S, d] -> (combine [G,S,E,C], dispatch [G,S,E,C], aux_loss)."""
    G, S, _ = xg.shape
    cap = max(1, int(S * e.top_k / e.n_experts * e.capacity_factor))
    logits = (xg @ p["router"]).astype(jnp.float32)          # [G,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, e.top_k)      # [G,S,k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    # one-hot per chosen expert: [G,S,k,E]
    sel = jax.nn.one_hot(gate_idx, e.n_experts, dtype=jnp.float32)
    # position of each (token, choice) within its expert queue
    pos_in_e = (jnp.cumsum(sel.reshape(G, S * e.top_k, e.n_experts), axis=1)
                .reshape(G, S, e.top_k, e.n_experts) - 1.0)
    keep = sel * (pos_in_e < cap)
    pos = jnp.einsum("gske,gske->gsk", pos_in_e, keep)       # chosen slot
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)     # [G,S,k,C]
    disp = jnp.einsum("gske,gskc->gsec", keep, pos_oh)       # [G,S,E,C]
    comb = jnp.einsum("gsk,gske,gskc->gsec", gate_vals, keep, pos_oh)
    # load-balance aux (Switch): E * sum_e f_e * P_e
    f_e = jnp.mean(jnp.sum(sel, axis=2), axis=(0, 1))        # frac routed
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = e.n_experts * jnp.sum(f_e * p_e) * e.aux_loss_coef
    return comb, disp, aux, cap


def moe_block(p, x, cfg):
    """x: [b, t, d] -> ([b, t, d], aux_loss)."""
    e = cfg.moe
    b, t, d = x.shape
    n = b * t
    g = max(1, n // e.group_tokens)
    xg = x.reshape(g, n // g, d)
    comb, disp, aux, cap = _route(p, xg, e)
    if e.dispatch == "einsum":
        xe = jnp.einsum("gsd,gsec->gecd", xg, disp.astype(x.dtype))
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) \
            * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
        ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
        y = jnp.einsum("gecd,gsec->gsd", ye, comb.astype(x.dtype))
    else:  # gather dispatch: indices instead of one-hot matmuls
        # token index occupying each (e, c) slot (or S -> zero pad row)
        S = xg.shape[1]
        slot_tok = jnp.einsum("gsec,s->gec", disp,
                              jnp.arange(S, dtype=jnp.float32))
        occupied = jnp.sum(disp, axis=1) > 0                  # [G,E,C]
        idx = jnp.where(occupied, slot_tok.astype(jnp.int32), S)
        xg_pad = jnp.concatenate(
            [xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
        xe = jnp.take_along_axis(
            xg_pad, idx.reshape(g, -1)[..., None], axis=1)
        xe = xe.reshape(g, e.n_experts, cap, d)
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) \
            * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
        ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
        y = jnp.einsum("gecd,gsec->gsd", ye, comb.astype(x.dtype))
    y = y.reshape(b, t, d)
    if e.n_shared:
        y = y + layers.mlp(p["shared"], x)
    if e.dense_residual_ff:
        y = y + layers.mlp(p["dense"], x)
    return y, aux
