"""Distributed majority-vote transports over the ``data`` (device) axis.

Input: per-device quantities laid out ``[P, D, *leaf]`` (P pods = edges,
D data slices = devices).  Output: per-pod vote ``[P, *leaf]``.

Transport matrix (DESIGN.md Sec. 2 "Vote transport"):

============  ==============  ===========================  =================
transport     wire format     HBM passes per local step    fallback rules
============  ==============  ===========================  =================
``ag_packed`` 1 bit/coord,    per leaf: read g (f32) ->    leaf minor dim
              per leaf        write words (1/256 of g),    % 32 != 0 ->
                              gather, unpack+vote fusion   ``ar_int8``
``ar_int8``   8 bits/coord    read signs, int tally        tally upcasts to
                              all-reduce, sgn              int16 when
                                                           D > 127 voters
``fused``     1 bit/coord,    ONE flat word buffer for     FSDP regime and
              one contiguous  the whole model: per-leaf    per-leaf callers
              word buffer     fused (g + rho*delta) ->     -> ``ag_packed``;
              (flatbuf        sign -> pack, word-level     model axis > 1 ->
              layout)         concat (1/32 of the tally),  shard_map program
                              ONE data-axis gather, ONE    on per-shard
                              popcount vote + update       buckets (kernels
                                                           per rank on TPU);
                                                           off-TPU -> pure
                                                           jnp (bit-ident.)
``mean`` /    32 bits/coord   full-precision weighted      --
``wmean``                     averaging (HierSGD)
============  ==============  ===========================  =================

``ag_packed``  (paper-faithful) -- each device contributes a bit-packed sign
    row (1 bit/coordinate, exactly the paper's uplink payload); the packed
    rows are all-gathered along ``data`` and every chip computes the same
    popcount vote -- this *is* the paper's "edge broadcasts the vote back",
    with zero additional downlink.

``ar_int8``  (beyond-paper optimized) -- the vote sgn(sum_k sgn g_k) is
    computed distributively via an int8 all-reduce of the sign tally
    (|sum| <= D <= 127 fits int8; larger D upcasts the tally to int16).
    8 bits/coordinate on the wire but a single reduction phase, and under
    FSDP the tally reduce-scatters straight onto the owning shard.

``fused``  (beyond-paper, flat-buffer) -- the whole model is bucketized by
    ``core.flatbuf`` into one 32*128-tile-aligned coordinate space; devices
    emit a single contiguous packed uplink row per step with the DC
    correction fused pre-sign (Alg. 2's device-side step), ONE gather moves
    it, and ONE fused popcount-vote produces the per-pod direction.  On a
    single-device TPU mesh the local compute runs the Pallas kernels
    (``kernels.sign_pack`` / ``kernels.vote_update``); on a multi-chip
    mesh with a >1 model axis the whole chain runs as a ``shard_map``
    program over a *sharded* flatbuf layout (per-model-shard buckets):
    each rank sign-packs its own bucket (Pallas on TPU), the packed
    words are all-gathered over ``data`` INSIDE the program -- the only
    collective -- and each rank votes/updates its local shard, so no
    whole-leaf gather and no unsharded bit tensor ever exist.
    Everywhere else a pure-jnp path with identical arithmetic runs
    (GSPMD partitions it).  All three sign transports are bit-identical
    (ties -> +1) by construction.  Requires the replicated regime.

State layouts (``AlgoConfig.state_layout``, see ``core.flatbuf``):

``tree`` (default) -- the master params are a pytree; every transport's
    vote is unflattened back to leaves and the descent update
    ``v <- v - mu*vote`` is a per-leaf tree map.
``flat`` -- the master params ARE the flat buffer (``flatbuf.FlatState``)
    for the whole run; any transport's direction is applied as ONE
    whole-buffer elementwise update, and ``transport="fused"`` goes
    further through :func:`fused_sign_vote_update`: the vote is never
    materialized -- ONE ``vote_update`` read-modify-write per pod applies
    ``v <- v - mu*MajorityVote(packed)`` over the packed-word buffer
    (in-place when compiled).  On meshes with a >1 model axis the
    buffer uses the SHARDED flatbuf layout (one bucket per model shard)
    and every buffer<->tree move plus the fused chain itself runs under
    ``shard_map`` (``core.shardflat`` / :func:`_fused_shard_map`) --
    the buffer, the packed words and the vote stay model-sharded end to
    end.  Bit-identical in trajectory to ``tree`` under every transport
    (the per-coordinate arithmetic is unchanged; asserted by
    tests/test_parity_matrix.py and the multi-chip
    tests/helpers/sharded_fused_check.py).  Replicated regime only.

All functions are pure jnp + sharding constraints: they lower to data-axis
collectives under GSPMD and degenerate to local arithmetic at P=D=1 (which
is how they are unit-tested against ``repro.core.signs``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import flatbuf, shardflat, signs
from repro.core.topology import Topology
from repro.kernels import ops as kops

PACK = signs.PACK_WIDTH

SIGN_TRANSPORTS = ("ag_packed", "ar_int8", "fused")


def _mask_bcast(mask: jax.Array | None, ndim_leaf: int):
    """[P, D] voter mask/weights -> broadcastable to [P, D, *leaf]."""
    if mask is None:
        return None
    return mask.reshape(mask.shape + (1,) * ndim_leaf)


def _tally_acc(weight_bound: int):
    """Smallest int dtype holding a tally of range ``weight_bound``
    (the weighted-vote generalization of the PR 1 D>127 promotion:
    promote on ``sum(w)``, not on the voter count)."""
    if weight_bound <= 127:
        return jnp.int8
    if weight_bound <= 32767:
        return jnp.int16
    return jnp.int32


def vote_ar_int8(topo: Topology, s_dev: jax.Array,
                 mask: jax.Array | None,
                 weight_bound: int | None = None) -> jax.Array:
    """sgn(sum_k w_k s_k) via an integer tally reduction over the device
    axis.

    mask: optional [P, D] voter mask OR nonnegative integer vote weights
    (``core.clients`` data shares; weight 0 abstains, and an edge whose
    whole quorum abstains returns vote 0).  The tally rides the wire in
    int8 while its range ``sum(w) <= 127`` fits (unit weights: the voter
    count D); wider ranges promote to int16/int32.  ``weight_bound`` is
    the *static* per-edge range ``max_q sum_k w_qk`` -- required for
    weighted masks (traced values cannot pick dtypes); ``None`` means
    unit weights and reproduces the original ``D > 127`` promotion rule
    (regression-tested).  Passing an integer-dtype weight array without
    a bound raises -- silently defaulting to the voter count would
    re-open the wrap this rule exists to prevent.
    """
    if (weight_bound is None and mask is not None
            and jnp.issubdtype(mask.dtype, jnp.integer)):
        raise ValueError(
            "vote_ar_int8: integer vote weights need an explicit static "
            "weight_bound (max per-edge sum(w)) to size the tally dtype; "
            "the voter-count default only covers {0,1} masks")
    bound = weight_bound if weight_bound is not None else s_dev.shape[1]
    acc = _tally_acc(bound)
    tally = s_dev.astype(acc)
    m = _mask_bcast(mask, s_dev.ndim - 2)
    if m is not None:
        tally = tally * m.astype(acc)
    tally = jnp.sum(tally, axis=1, dtype=acc)                  # [P, *leaf]
    # with abstentions the tie rule is 2*pos >= n_eff  <=>  tally >= 0
    vote = signs.sgn(tally.astype(jnp.int32))
    if mask is not None:
        n_eff = jnp.sum(mask.astype(jnp.int32), axis=1)
        n_eff = n_eff.reshape((-1,) + (1,) * (vote.ndim - 1))
        vote = jnp.where(n_eff > 0, vote, jnp.int8(0))
    return vote


def vote_ag_packed(topo: Topology, s_dev: jax.Array,
                   mask: jax.Array | None, leaf_spec: P) -> jax.Array:
    """Bit-packed all-gather + local popcount vote (1 bit/coord wire).

    s_dev: [P, D, *leaf] int8 signs; leaf minor dim % 32 == 0 required;
    mask: optional [P, D] voter mask or integer vote weights (weighted
    popcount; an empty quorum abstains -> vote 0).
    The packed words are constrained to be replicated along ``data`` --
    that resharding is the all-gather whose operand is 1/32 the int8 tally
    (and 1/256 the fp32 gradient) -- then every chip votes locally.
    """
    *lead, minor = s_dev.shape
    assert minor % PACK == 0, "caller guarantees minor % 32 == 0"
    words = signs.pack_signs(s_dev)                            # [P, D, *l, minor/32]
    # device-axis all-gather of the 1-bit payload: keep every other dim's
    # sharding, drop 'data' from dim 1.
    gathered_spec = P(topo.pod_axis, None, *leaf_spec)
    words = topo.constrain(words, gathered_spec)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)        # [P,D,*l,w,32]
    bits = bits.astype(jnp.int8)
    if mask is not None:
        # mask may carry integer vote weights (weighted popcount): the
        # per-voter product runs in int32 so weights cannot wrap
        m = _mask_bcast(mask, bits.ndim - 2)
        pos = jnp.sum(bits.astype(jnp.int32) * m.astype(jnp.int32),
                      axis=1, dtype=jnp.int32)
        n_eff = jnp.sum(mask.astype(jnp.int32), axis=1)
        n_eff = n_eff.reshape((-1,) + (1,) * (pos.ndim - 1))
    else:
        pos = jnp.sum(bits, axis=1, dtype=jnp.int32)           # [P,*l,w,32]
        n_eff = s_dev.shape[1]
    vote = jnp.where(2 * pos >= n_eff, jnp.int8(1), jnp.int8(-1))
    if mask is not None:   # empty quorum abstains
        vote = jnp.where(n_eff > 0, vote, jnp.int8(0))
    return vote.reshape(s_dev.shape[:1] + s_dev.shape[2:])     # [P, *leaf]


# ---------------------------------------------------------------------------
# Fused flat-buffer transport
# ---------------------------------------------------------------------------

_UNROLL_VOTERS = 64     # static unroll bound for the popcount accumulation


def _popcount_vote_words(words: jax.Array, mask: jax.Array | None,
                         n_dev: int) -> jax.Array:
    """[P, D, W] packed words (+ [P, D] mask/weights) -> [P, W*32] int8 vote.

    ``mask`` may carry integer vote weights (the weighted popcount of
    ``core.clients``): the per-voter bit-plane is scaled by its weight
    in int32 and the tie rule compares against the participating weight
    sum; an empty quorum abstains (vote 0).

    For small static D the voter axis is unrolled into an add chain of
    per-voter unpacks, so the [P, D, W, 32] bit tensor (an 8x HBM blow-up
    of the wire payload) never materializes -- XLA fuses the chain into
    one sweep whose operand is the packed words themselves.  Large D
    falls back to the reduction form.
    """
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    d = words.shape[1]

    def bits_of(w_d):                                          # [P,W] words
        return ((w_d[..., None] >> shifts) & jnp.uint32(1)
                ).astype(jnp.int32)                            # [P,W,32]

    if d <= _UNROLL_VOTERS:
        pos = None
        for k in range(d):
            b = bits_of(words[:, k])
            if mask is not None:
                b = b * mask[:, k].astype(jnp.int32)[:, None, None]
            pos = b if pos is None else pos + b
    else:
        bits = (words[..., None] >> shifts) & jnp.uint32(1)    # [P,D,W,32]
        if mask is not None:
            m = mask.astype(jnp.int32)[:, :, None, None]
            pos = jnp.sum(bits.astype(jnp.int32) * m, axis=1,
                          dtype=jnp.int32)
        else:
            pos = jnp.sum(bits.astype(jnp.int8), axis=1,
                          dtype=jnp.int32)                     # [P,W,32]
    if mask is not None:
        n_eff = jnp.sum(mask.astype(jnp.int32), axis=1)[:, None, None]
    else:
        n_eff = n_dev
    vote = jnp.where(2 * pos >= n_eff, jnp.int8(1), jnp.int8(-1))
    if mask is not None:   # empty quorum abstains
        vote = jnp.where(n_eff > 0, vote, jnp.int8(0))
    return vote.reshape(vote.shape[0], -1)                     # [P, W*32]


# ---------------------------------------------------------------------------
# Streamed virtual-client tally (ClientConfig.mode="stream")
# ---------------------------------------------------------------------------
#
# The streamed client sweep never widens the voter axis: each client's
# signs are folded into a persistent SIGNED tally  t += w_c * sgn(u_c)
# (in the ``_tally_acc(weight_bound)`` dtype -- every partial sum is
# bounded by the running participating-weight sum, so the accumulator
# can never transiently overflow), and the sign threshold is DEFERRED
# until after the client loop:  t = 2*pos - n_eff, so ``t >= 0`` is
# exactly the merged path's ``2*pos >= n_eff`` tie rule and the two
# modes are bitwise identical by integer associativity.

def tally_dtype(weight_bound: int):
    """Accumulator dtype of the streamed tally -- the SAME promotion
    rule as ``vote_ar_int8`` (``_tally_acc``): the signed tally has
    range ``sum(w)``, so it promotes on the weight bound, not on the
    client count."""
    return _tally_acc(weight_bound)


def tally_add_signs(tally: jax.Array, s: jax.Array,
                    weights: jax.Array) -> jax.Array:
    """One client's weighted sign contribution: ``tally + w * s``.

    tally: [P, D, *leaf] signed tally (``tally_dtype`` ints); s:
    [P, D, *leaf] int8 signs of ONE client; weights: [P, D] nonnegative
    integer vote weights of that client this round (0 = abstains).
    The product runs in int32 and narrows back to the tally dtype --
    exact, since every partial tally is bounded by ``weight_bound``.
    """
    w = weights.astype(jnp.int32).reshape(
        weights.shape + (1,) * (s.ndim - 2))
    return tally + (s.astype(jnp.int32) * w).astype(tally.dtype)


def tally_accumulate_words(words: jax.Array, weights: jax.Array,
                           tally: jax.Array) -> jax.Array:
    """Tally-accumulate variant of ``_popcount_vote_words``: fold ONE
    client's packed sign words into the signed tally.

    words: [P, D, W] uint32 (the client's 1-bit uplink payload);
    weights: [P, D] integer vote weights; tally: [P, D, W*32] signed
    tally.  Per coordinate ``tally += w * (2*bit - 1)`` -- the same
    weighted popcount as the merged transports, deferred: summing these
    contributions over clients gives ``t = 2*pos - n_eff``.
    """
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = ((words[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    sgn_c = 2 * bits - 1                                       # [P,D,W,32]
    add = sgn_c * weights.astype(jnp.int32)[:, :, None, None]
    return tally + add.reshape(tally.shape).astype(tally.dtype)


def tally_vote(tally: jax.Array, n_eff: jax.Array) -> jax.Array:
    """Deferred threshold of the streamed sweep: signed tally -> vote.

    tally: [P, *leaf] edge tally (summed over devices; int); n_eff:
    [P] int32 participating weight sum.  ``t >= 0 -> +1`` is exactly
    the merged tie rule ``2*pos >= n_eff`` (t = 2*pos - n_eff), so
    weighted ties still resolve to sgn(0) = +1; an empty quorum
    (n_eff == 0) abstains with vote 0.
    """
    t = tally.astype(jnp.int32)
    vote = jnp.where(t >= 0, jnp.int8(1), jnp.int8(-1))
    n = n_eff.reshape((-1,) + (1,) * (vote.ndim - 1))
    return jnp.where(n > 0, vote, jnp.int8(0))


def _fused_kernel_bufs(layout, u_dev, delta_tree, delta_buf, rho):
    """Fold rule + flat views for the Pallas route (shared by the vote-
    only and the flat-state vote+update entry points; the correction may
    arrive as a pytree or as a flat buffer).

    The sign_pack kernel adds rho*delta in f32; folding it there is
    exact only when the reference per-leaf arithmetic is f32 too.
    Mixed/low-precision trees pre-add in each leaf's own dtype
    (identical to the tree path) to keep the transports bit-identical
    at ULP sign boundaries.
    """
    leaves = layout.treedef.flatten_up_to(u_dev)
    have_delta = (delta_tree is not None or delta_buf is not None) and rho
    fold_in_kernel = (have_delta
                      and all(leaf.dtype == jnp.float32 for leaf in leaves))
    if have_delta and not fold_in_kernel:
        if delta_tree is None:
            delta_tree = flatbuf.unflatten_tree(layout, delta_buf,
                                                batch_dims=1, cast=False)
        u_dev = jax.tree.map(
            lambda u, dl: u + rho * dl[:, None].astype(u.dtype),
            u_dev, delta_tree)
    # flatten in the promoted dtype over the u leaves: widening casts
    # never move a value across zero, so the signs stay bit-identical to
    # pack_tree's per-leaf-dtype arithmetic
    dt = leaves[0].dtype
    for leaf in leaves[1:]:
        dt = jnp.promote_types(dt, leaf.dtype)
    u_buf = flatbuf.flatten_tree(layout, u_dev, batch_dims=2, dtype=dt)
    if not jnp.issubdtype(u_buf.dtype, jnp.floating):
        # EF hands pre-signed int8 trees in; the kernels take float
        # blocks (int8 VMEM tiling differs), and +-1 casts exactly.
        u_buf = u_buf.astype(jnp.float32)
    d_buf = None
    if fold_in_kernel:
        d_buf = (delta_buf.astype(u_buf.dtype) if delta_buf is not None
                 else flatbuf.flatten_tree(layout, delta_tree, batch_dims=1,
                                           dtype=u_buf.dtype))
    return u_buf, d_buf


def _packed_vote(topo, layout, u_dev, delta_tree, rho, mask):
    """jnp route: per-leaf fused pack (correction pre-sign), ONE
    data-axis gather of the 1-bit payload, one popcount -> [P, n_pad]."""
    n_dev = layout.treedef.flatten_up_to(u_dev)[0].shape[1]
    words = flatbuf.pack_tree(layout, u_dev, batch_dims=2,
                              delta=delta_tree, rho=rho,
                              delta_batch_dims=1)
    # the device->edge uplink: all-gather the 1-bit payload over 'data'
    words = topo.constrain(words, P(topo.pod_axis, topo.data_axis, None))
    words = topo.constrain(words, P(topo.pod_axis, None, None))
    return _popcount_vote_words(words, mask, n_dev)


def _fused_shard_map(topo: Topology, layout: flatbuf.FlatLayout, u_dev,
                     delta_tree, delta_buf, rho: float,
                     mask: jax.Array | None, v_buf: jax.Array | None,
                     mu, mu_static: float | None):
    """The multi-chip fused transport: ONE shard_map program per step.

    Per rank (pod p, device d, model shard m): fuse the DC correction
    pre-sign and pack the rank's own bucket of the sharded flatbuf
    layout (Pallas ``sign_pack`` on TPU, pure-jnp elsewhere -- same
    arithmetic as the unsharded path per coordinate), all-gather the
    packed words over the ``data`` axis -- the only collective in the
    program, 1 bit/coordinate of the LOCAL shard -- then popcount-vote
    and (when ``v_buf`` is given) apply ``v <- v - mu*vote`` on the
    local bucket via the ``vote_update`` read-modify-write.  No leaf is
    ever gathered over ``model`` and no unsharded bit tensor exists.

    Returns the updated [P, n_pad] buffer when ``v_buf`` is given, else
    the per-pod vote as a [P, *leaf] int8 pytree (unflattened inside
    the program; sharded leaves come back model-sharded on their
    ``shard_dim``, per-bucket copies replicated -- every rank computes
    the identical vote for them by construction).

    Uneven sharded leaves enter and leave the program in their padded
    shapes (``flatbuf.pad_tree`` / ``unpad_tree``): the zero tail packs
    to +1 sign bits -- the standard don't-care padding -- and is sliced
    off any returned vote tree, so callers only ever see logical
    extents.
    """
    bucket = layout.bucket()
    u_dev = flatbuf.pad_tree(layout, u_dev, 2)
    if delta_tree is not None:
        delta_tree = flatbuf.pad_tree(layout, delta_tree, 1)
    mode = kops.fused_kernel_mode(topo.mesh.size, shard_mapped=True)
    use_kernel = mode in ("pallas", "interpret")
    interpret = mode == "interpret"
    want_update = v_buf is not None
    fold_mu = (want_update and use_kernel and mu_static is not None
               and v_buf.dtype == jnp.float32)

    names = ["u"]
    args = [u_dev]
    in_specs = [shardflat.leaf_specs(topo, layout, 2)]
    if delta_tree is not None and rho:
        names.append("dt")
        args.append(delta_tree)
        in_specs.append(shardflat.leaf_specs(topo, layout, 1))
    if delta_buf is not None and rho:
        names.append("db")
        args.append(delta_buf)
        in_specs.append(shardflat.buf_spec(topo, layout, 1))
    if mask is not None:
        names.append("mask")
        args.append(mask)
        in_specs.append(P(topo.pod_axis, None))
    if want_update:
        names.append("v")
        args.append(v_buf)
        in_specs.append(shardflat.buf_spec(topo, layout, 1))
        if not fold_mu:
            names.append("mu")
            args.append(mu)
            in_specs.append(P())

    def program(*local):
        kw = dict(zip(names, local))
        u_l, dt_l, db_l = kw["u"], kw.get("dt"), kw.get("db")
        m_l, v_l = kw.get("mask"), kw.get("v")
        if use_kernel:
            u2, d2 = _fused_kernel_bufs(bucket, u_l, dt_l, db_l, rho)
            words = kops.fused_pack_flat(u2, d2, rho, interpret=interpret)
        else:
            if db_l is not None:
                dt_l = flatbuf.unflatten_tree(bucket, db_l, batch_dims=1,
                                              cast=False)
            words = flatbuf.pack_tree(bucket, u_l, batch_dims=2,
                                      delta=dt_l, rho=rho,
                                      delta_batch_dims=1)
        # the device->edge uplink: gather the 1-bit payload over 'data'
        words = jax.lax.all_gather(words, topo.data_axis, axis=1,
                                   tiled=True)
        if fold_mu:
            return kops.fused_vote_update_words(
                words, v_l, m_l, float(mu_static), interpret=interpret)
        if use_kernel:
            vote = kops.fused_vote_update_words(
                words, None, m_l, -1.0, interpret=interpret
            ).astype(jnp.int8)
        else:
            # post-gather the voter axis holds every (virtual) client:
            # its extent is the correct unmasked quorum size
            vote = _popcount_vote_words(words, m_l, words.shape[1])
        if want_update:
            return v_l - kw["mu"] * vote.astype(v_l.dtype)
        return flatbuf.unflatten_tree(bucket, vote, batch_dims=1,
                                      cast=False)

    out_specs = (shardflat.buf_spec(topo, layout, 1) if want_update
                 else shardflat.leaf_specs(topo, layout, 1))
    fn = shard_map(program, mesh=topo.mesh, in_specs=tuple(in_specs),
                   out_specs=out_specs, check_rep=False)
    out = fn(*args)
    if want_update:
        return out
    return flatbuf.unpad_tree(layout, out, 1)


def fused_sign_vote(topo: Topology, u_dev, delta=None, rho: float = 0.0,
                    mask: jax.Array | None = None, specs=None):
    """Whole-model fused sign transport: pytree in, vote pytree out.

    u_dev: pytree of [P, D, *leaf] pre-sign directions (gradients after
    momentum/EF; the voter axis may be the merged virtual-client axis
    [P, D*K, *leaf] of ``core.clients``); delta: optional pytree of
    [P, *leaf] DC corrections, fused pre-sign as ``u + rho * delta``
    exactly like the per-leaf path; mask: optional [P, D] voter mask or
    integer vote weights (weighted popcount, empty quorum abstains).
    Returns the per-pod vote pytree ([P, *leaf] int8), bit-identical to
    ``ag_packed``/``ar_int8`` applied leaf-wise (ties -> +1).

    Chain: per-leaf fused sign+pack into ONE contiguous word buffer
    (``flatbuf`` layout; the f32 flat buffer is never materialized on the
    jnp path), one data-axis gather of the packed words, one popcount
    vote.  On a single-device TPU mesh the local sweeps instead run the
    Pallas kernels over the flat f32 view (``kernels.ops``).

    specs: optional per-leaf PartitionSpec pytree (leaf dims).  On a
    mesh with a >1 model axis this switches to the sharded flatbuf
    layout + shard_map program (:func:`_fused_shard_map`): TP-sharded
    leaves stay sharded end to end and the Pallas kernels run per rank.
    """
    if specs is not None and topo.model_shards > 1:
        layout = flatbuf.make_layout(
            u_dev, batch_dims=2,
            sharding=shardflat.model_sharding(topo, specs))
        if layout.shards > 1:
            return _fused_shard_map(topo, layout, u_dev, delta, None, rho,
                                    mask, None, None, None)
    layout = flatbuf.make_layout(u_dev, batch_dims=2)
    mode = kops.fused_kernel_mode(topo.mesh.size)
    if mode in ("pallas", "interpret"):
        u_buf, d_buf = _fused_kernel_bufs(layout, u_dev, delta, None, rho)
        vote = kops.fused_sign_vote_flat(
            u_buf, d_buf, rho, mask, interpret=(mode == "interpret"))
    else:
        vote = _packed_vote(topo, layout, u_dev, delta, rho, mask)
    vote = topo.constrain(vote, P(topo.pod_axis, None))
    return flatbuf.unflatten_tree(layout, vote, batch_dims=1, cast=False)


def fused_sign_vote_update(topo: Topology, layout: flatbuf.FlatLayout,
                           u_dev, delta_buf: jax.Array | None,
                           rho: float, mask: jax.Array | None,
                           v_buf: jax.Array, mu,
                           mu_static: float | None = None) -> jax.Array:
    """Flat-state fused transport: ``v_buf <- v_buf - mu * vote`` whole-model.

    u_dev: pytree of [P, D, *leaf] pre-sign directions (uniform dtype;
    D may be the merged virtual-client axis D*K); delta_buf: optional
    [P, n_pad] DC correction buffer (delta dtype); mask: optional
    [P, D] voter mask or integer vote weights (weighted popcount, empty
    quorum abstains -> that edge's buffer is untouched this step);
    v_buf: [P, n_pad] master buffer; mu: traced step-size scalar;
    mu_static: the Python value of mu when it is step-independent -- lets
    the Pallas route fold the update into the ``vote_update`` kernel
    (ONE read-modify-write HBM pass over the whole model, no per-leaf
    dispatch).  Votes are bit-identical to :func:`fused_sign_vote` and
    the update arithmetic matches the tree-state per-leaf
    ``v - mu*vote.astype(v.dtype)`` exactly.

    A sharded ``layout`` (``layout.shards > 1``, from
    ``flatbuf.make_layout(..., sharding=...)``) routes through the
    shard_map program (:func:`_fused_shard_map`): the buffer stays
    model-axis sharded for the whole read-modify-write.
    """
    if layout.shards > 1:
        new_v = _fused_shard_map(topo, layout, u_dev, None, delta_buf,
                                 rho, mask, v_buf, mu, mu_static)
        return topo.constrain(new_v, shardflat.buf_spec(topo, layout, 1))
    mode = kops.fused_kernel_mode(topo.mesh.size)
    if mode in ("pallas", "interpret"):
        u_buf, d_buf = _fused_kernel_bufs(layout, u_dev, None, delta_buf,
                                          rho)
        interpret = (mode == "interpret")
        if mu_static is not None and v_buf.dtype == jnp.float32:
            # the kernel updates in f32: exact vs the tree path only for
            # f32 masters (mu_static rounds identically)
            new_v = kops.fused_vote_update_flat(
                u_buf, d_buf, rho, mask, v_buf, float(mu_static),
                interpret=interpret)
        else:
            vote = kops.fused_sign_vote_flat(u_buf, d_buf, rho, mask,
                                             interpret=interpret)
            new_v = v_buf - mu * vote.astype(v_buf.dtype)
    else:
        delta_tree = (flatbuf.unflatten_tree(layout, delta_buf,
                                             batch_dims=1, cast=False)
                      if delta_buf is not None and rho else None)
        vote = _packed_vote(topo, layout, u_dev, delta_tree, rho, mask)
        new_v = v_buf - mu * vote.astype(v_buf.dtype)
    return topo.constrain(new_v, P(topo.pod_axis, None))


def majority_vote_dev(topo: Topology, s_dev: jax.Array,
                      mask: jax.Array | None, transport: str,
                      leaf_spec: P,
                      weight_bound: int | None = None) -> jax.Array:
    """Vote [P, D, *leaf] -> [P, *leaf]; dispatch on transport + leaf shape.

    ``mask`` may carry integer vote weights (see the per-transport
    docs); ``weight_bound`` is the static per-edge tally range for the
    int-tally transport's dtype promotion (None = unit weights).

    Per-leaf callers (FSDP lift) route ``fused`` to ``ag_packed`` -- the
    flat-buffer chain only pays off when the whole tree is bucketized.
    """
    if (transport in ("ag_packed", "fused")
            and s_dev.shape[-1] % PACK == 0):
        return vote_ag_packed(topo, s_dev, mask, leaf_spec)
    return vote_ar_int8(topo, s_dev, mask, weight_bound=weight_bound)


def weighted_mean_dev(topo: Topology, g_dev: jax.Array,
                      dev_weights: jax.Array, clients: int = 1) -> jax.Array:
    """Full-precision edge aggregation  sum_k (|D_qk|/D_q) g_k  -> [P, *leaf].

    clients: with K > 1 merged virtual clients the voter-axis reduction
    is re-associated as a zeros-initialized ``fori_loop`` fold over each
    slice's K clients (multiply INSIDE the loop body, so XLA emits the
    same mul+add per iteration) followed by the device sum -- the EXACT
    float op order the streamed client sweep
    (``ClientConfig.mode="stream"``) produces with its ``fori_loop``
    accumulator, so the two modes stay bitwise identical on the
    full-precision aggregations (anchor pass, mean methods) too.  A
    Python-unrolled chain is NOT equivalent: XLA compiles the unrolled
    adds (and a hoisted multiply) with different rounding than the loop
    body.  ``clients=1`` is the original single ``jnp.sum``.
    """
    if clients <= 1:
        w = dev_weights.reshape(dev_weights.shape + (1,) * (g_dev.ndim - 2))
        return jnp.sum(g_dev * w.astype(g_dev.dtype), axis=1)
    p, dk = g_dev.shape[:2]
    g3 = g_dev.reshape((p, dk // clients, clients) + g_dev.shape[2:])
    w3 = dev_weights.reshape(p, dk // clients, clients)

    def body(c, acc):
        g_c = jax.lax.dynamic_index_in_dim(g3, c, axis=2, keepdims=False)
        w_c = jax.lax.dynamic_index_in_dim(w3, c, axis=2, keepdims=False)
        w_c = w_c.reshape(w_c.shape + (1,) * (g_c.ndim - 2))
        return acc + g_c * w_c.astype(g_c.dtype)

    acc = jax.lax.fori_loop(
        0, clients, body,
        jnp.zeros(g3.shape[:2] + g3.shape[3:], g_dev.dtype))
    return jnp.sum(acc, axis=1)


# ---------------------------------------------------------------------------
# Streamed client sweep: per-leaf and fused tally entry points
# ---------------------------------------------------------------------------

def tally_vote_dev(topo: Topology, tally: jax.Array, n_eff: jax.Array,
                   leaf_spec: P) -> jax.Array:
    """[P, D, *leaf] streamed per-device tally -> [P, *leaf] int8 vote.

    The data-axis reduction of the streamed sweep: the int tally is the
    per-step uplink payload (ONE device-axis reduction per local step,
    not per client), summed in int32 and thresholded by
    :func:`tally_vote`.  Integer associativity makes the result bitwise
    identical to the merged-axis weighted popcount of any transport.
    """
    t = topo.constrain(tally, topo.dev_spec(*leaf_spec))
    ts = jnp.sum(t.astype(jnp.int32), axis=1)                  # [P, *leaf]
    return tally_vote(ts, n_eff)


def fused_sign_tally_accumulate(topo: Topology, layout: flatbuf.FlatLayout,
                                u_dev, delta_tree, delta_buf,
                                rho: float, weights: jax.Array,
                                tally: jax.Array) -> jax.Array:
    """Streamed-client device-side half of the fused transport: fold ONE
    client's (DC-corrected) signs into the persistent tally buffer.

    u_dev: pytree of [P, D, *leaf] pre-sign directions of the CURRENT
    client (physical device axis D, never the merged D*K); delta_tree /
    delta_buf: optional DC correction ([P, *leaf] tree or [P, n_pad]
    buffer), fused pre-sign exactly like :func:`fused_sign_vote`;
    weights: [P, D] integer vote weights of this client this round;
    tally: [P, D, n_pad] signed tally (``tally_dtype(weight_bound)``).
    Returns the updated tally.  No collective runs here -- the data
    exchange of the streamed sweep happens once per local step in
    :func:`fused_tally_finish`.

    On TPU the pack -> weighted sign -> tally read-modify-write is ONE
    Pallas sweep (``kernels.tally_acc``, aliased in place when
    compiled); elsewhere the bit-identical jnp route packs via
    ``flatbuf.pack_tree`` and accumulates with
    :func:`tally_accumulate_words`.  A sharded layout (``layout.shards
    > 1``) runs the same per-rank program under shard_map on each
    rank's bucket.
    """
    if layout.shards > 1:
        return _tally_acc_shard_map(topo, layout, u_dev, delta_tree,
                                    delta_buf, rho, weights, tally)
    mode = kops.fused_kernel_mode(topo.mesh.size)
    if mode in ("pallas", "interpret"):
        u_buf, d_buf = _fused_kernel_bufs(layout, u_dev, delta_tree,
                                          delta_buf, rho)
        return kops.fused_tally_acc_flat(u_buf, d_buf, rho, weights, tally,
                                         interpret=(mode == "interpret"))
    if delta_buf is not None and rho:
        delta_tree = flatbuf.unflatten_tree(layout, delta_buf, batch_dims=1,
                                            cast=False)
    words = flatbuf.pack_tree(layout, u_dev, batch_dims=2, delta=delta_tree,
                              rho=rho, delta_batch_dims=1)
    return tally_accumulate_words(words, weights, tally)


def _tally_acc_shard_map(topo: Topology, layout: flatbuf.FlatLayout, u_dev,
                         delta_tree, delta_buf, rho: float,
                         weights: jax.Array, tally: jax.Array) -> jax.Array:
    """Per-client accumulate of the sharded streamed fused path.

    One shard_map program with ZERO collectives: rank (p, d, m) packs
    its own model-axis bucket of this client's directions and folds the
    weighted signs into its local [1, 1, bucket_pad] tally block.
    """
    bucket = layout.bucket()
    u_dev = flatbuf.pad_tree(layout, u_dev, 2)
    if delta_tree is not None:
        delta_tree = flatbuf.pad_tree(layout, delta_tree, 1)
    mode = kops.fused_kernel_mode(topo.mesh.size, shard_mapped=True)
    use_kernel = mode in ("pallas", "interpret")
    interpret = mode == "interpret"

    names = ["u", "t", "w"]
    args = [u_dev, tally, weights]
    in_specs = [shardflat.leaf_specs(topo, layout, 2),
                shardflat.buf_spec(topo, layout, 2),
                P(topo.pod_axis, topo.data_axis)]
    if delta_tree is not None and rho:
        names.append("dt")
        args.append(delta_tree)
        in_specs.append(shardflat.leaf_specs(topo, layout, 1))
    if delta_buf is not None and rho:
        names.append("db")
        args.append(delta_buf)
        in_specs.append(shardflat.buf_spec(topo, layout, 1))

    def program(*local):
        kw = dict(zip(names, local))
        u_l, t_l, w_l = kw["u"], kw["t"], kw["w"]
        dt_l, db_l = kw.get("dt"), kw.get("db")
        if use_kernel:
            u2, d2 = _fused_kernel_bufs(bucket, u_l, dt_l, db_l, rho)
            return kops.fused_tally_acc_flat(u2, d2, rho, w_l, t_l,
                                             interpret=interpret)
        if db_l is not None:
            dt_l = flatbuf.unflatten_tree(bucket, db_l, batch_dims=1,
                                          cast=False)
        words = flatbuf.pack_tree(bucket, u_l, batch_dims=2, delta=dt_l,
                                  rho=rho, delta_batch_dims=1)
        return tally_accumulate_words(words, w_l, t_l)

    fn = shard_map(program, mesh=topo.mesh, in_specs=tuple(in_specs),
                   out_specs=shardflat.buf_spec(topo, layout, 2),
                   check_rep=False)
    return fn(*args)


def fused_tally_finish(topo: Topology, layout: flatbuf.FlatLayout,
                       tally: jax.Array, n_eff: jax.Array,
                       v_buf: jax.Array | None, mu):
    """Edge-side half of the streamed fused transport: reduce the
    per-device tallies over ``data`` ONCE per local step, defer-threshold
    into the vote, and optionally apply ``v <- v - mu*vote``.

    tally: [P, D, n_pad] accumulated signed tallies (all K clients
    folded in); n_eff: [P] int32 participating weight sum of the round.
    With ``v_buf`` (flat state) returns the updated [P, n_pad] buffer;
    without it returns the vote as a [P, *leaf] int8 pytree -- mirroring
    :func:`fused_sign_vote_update` / :func:`fused_sign_vote`.

    A sharded layout runs as ONE shard_map program whose only
    collective is the data-axis all-gather of the (already
    client-reduced) local tallies -- the streamed analogue of the
    merged path's packed-word gather.
    """
    if layout.shards > 1:
        bucket = layout.bucket()
        want_update = v_buf is not None
        names = ["t", "n"]
        args = [tally, n_eff]
        in_specs = [shardflat.buf_spec(topo, layout, 2), P(topo.pod_axis)]
        if want_update:
            names += ["v", "mu"]
            args += [v_buf, mu]
            in_specs += [shardflat.buf_spec(topo, layout, 1), P()]

        def program(*local):
            kw = dict(zip(names, local))
            # the ONE per-step collective of the streamed sweep
            t = jax.lax.all_gather(kw["t"], topo.data_axis, axis=1,
                                   tiled=True)
            ts = jnp.sum(t.astype(jnp.int32), axis=1)          # [1, n_l]
            vote = tally_vote(ts, kw["n"])
            if want_update:
                return kw["v"] - kw["mu"] * vote.astype(kw["v"].dtype)
            return flatbuf.unflatten_tree(bucket, vote, batch_dims=1,
                                          cast=False)

        out_specs = (shardflat.buf_spec(topo, layout, 1) if want_update
                     else shardflat.leaf_specs(topo, layout, 1))
        fn = shard_map(program, mesh=topo.mesh, in_specs=tuple(in_specs),
                       out_specs=out_specs, check_rep=False)
        out = fn(*args)
        if want_update:
            return topo.constrain(out, shardflat.buf_spec(topo, layout, 1))
        return flatbuf.unpad_tree(layout, out, 1)
    # the device->edge uplink: gather the int tallies over 'data'
    t = topo.constrain(tally, P(topo.pod_axis, topo.data_axis, None))
    t = topo.constrain(t, P(topo.pod_axis, None, None))
    ts = jnp.sum(t.astype(jnp.int32), axis=1)                  # [P, n_pad]
    vote = tally_vote(ts, n_eff)
    vote = topo.constrain(vote, P(topo.pod_axis, None))
    if v_buf is None:
        return flatbuf.unflatten_tree(layout, vote, batch_dims=1,
                                      cast=False)
    return topo.constrain(v_buf - mu * vote.astype(v_buf.dtype),
                          P(topo.pod_axis, None))


# ---------------------------------------------------------------------------
# Pod (edge -> cloud) tier
# ---------------------------------------------------------------------------

def pod_weighted_average(topo: Topology, v: jax.Array,
                         edge_weights: jax.Array) -> jax.Array:
    """Cloud aggregation  w = sum_q (D_q/N) v_q, broadcast back to [P, ...].

    v: [P, *leaf].  Lowers to a pod-axis all-reduce (the edge->cloud model
    exchange, every T_E steps).
    """
    w = edge_weights.reshape((-1,) + (1,) * (v.ndim - 1)).astype(v.dtype)
    glob = jnp.sum(v * w, axis=0, keepdims=True)               # [1, *leaf]
    return jnp.broadcast_to(glob, v.shape)
