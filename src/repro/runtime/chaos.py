"""Deterministic chaos engine: seeded fault schedules -> membership arrays.

A ``ChaosSchedule`` is a set of :class:`ChaosEvent`\\ s pinned to step
indices.  :class:`FaultInjector` holds one (built explicitly, from the
legacy ``{step: (kind, pod, dev)}`` dict form, or generated from a seed
-- same seed, same schedule, property-tested) and the same schedule
drives three consumers with identical semantics:

  * the live training driver (``launch/train.py --chaos``) applies the
    events to its :class:`~repro.runtime.elastic.Membership` step by
    step (and simulates nan-loss -> restore-and-replay through
    ``checkpoint/store.py``);
  * :func:`compile_schedule` replays the events against a fresh copy of
    the membership and emits the per-step ``(edge_weights, dev_weights,
    mask)`` arrays -- the pure-function form used by the parity tests;
  * the ``ref_fed`` oracle consumes the SAME compiled arrays as
    per-round / per-tau masks and weights (``device_mask_steps`` /
    ``edge_weights_agg``), so chaos cells are bitwise-comparable.

Event kinds:
  ``client``     kill one virtual client (pod, dev, client)
  ``device``     kill a device slice -- all K clients of (pod, dev)
  ``pod``        kill a whole pod
  ``heartbeat``  heartbeat loss: the target goes silent and is swept
                 out by the timeout (exercises ``Membership.sweep``)
  ``straggler``  straggler escalation demotes the target to abstention
                 (``Membership.demote``; bitwise a sampled-out client)
  ``recover``    the target re-joins (live again, fresh heartbeat)
  ``nan``        simulated numeric blow-up: the driver treats the step's
                 loss as non-finite and restores the newest checkpoint,
                 then replays (cursor-addressable batches + compiled
                 membership arrays make the replay deterministic).
                 Fires ONCE per scheduled step (otherwise replay would
                 re-trigger it forever); ignored by the compiler.

Events at step ``s`` apply BEFORE step ``s`` runs.  All schedules are
plain data: injectors with equal schedules compare equal.
"""
from __future__ import annotations

import copy
import dataclasses

import numpy as np

from repro.runtime.elastic import Membership, MembershipArrays

EVENT_KINDS = ("client", "device", "pod", "heartbeat", "straggler",
               "recover", "nan")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    step: int
    kind: str
    pod: int = 0
    dev: int | None = None
    client: int | None = None

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown chaos event kind {self.kind!r}; "
                             f"one of {EVENT_KINDS}")


class FaultInjector:
    """A deterministic chaos schedule, addressable by step.

    ``schedule`` is an iterable of :class:`ChaosEvent`, or the legacy
    ``{step: (kind, pod, dev)}`` dict (one event per step, device
    granularity) that the pre-chaos driver spoke.
    """

    def __init__(self, schedule):
        if isinstance(schedule, dict):
            events = [ChaosEvent(int(s), kind, pod, dev)
                      for s, (kind, pod, dev) in schedule.items()]
        else:
            events = list(schedule)
        self.events: tuple[ChaosEvent, ...] = tuple(
            sorted(events, key=lambda e: e.step))
        self._by_step: dict[int, tuple[ChaosEvent, ...]] = {}
        for ev in self.events:
            self._by_step[ev.step] = self._by_step.get(ev.step, ()) + (ev,)
        self._nan_fired: set[int] = set()

    def __eq__(self, other):
        return (isinstance(other, FaultInjector)
                and self.events == other.events)

    def __repr__(self):
        return f"FaultInjector({len(self.events)} events)"

    @property
    def horizon(self) -> int:
        """First step index past the last scheduled event."""
        return self.events[-1].step + 1 if self.events else 0

    def at(self, step: int) -> tuple[ChaosEvent, ...]:
        """All events scheduled for ``step`` (possibly empty)."""
        return self._by_step.get(step, ())

    def nan_due(self, step: int) -> bool:
        """True exactly ONCE per scheduled nan step: the first pass
        blows up, the post-restore replay of the same step does not."""
        if step in self._nan_fired:
            return False
        if any(ev.kind == "nan" for ev in self.at(step)):
            self._nan_fired.add(step)
            return True
        return False

    @classmethod
    def seeded(cls, seed: int, steps: int, pods: int, devices: int,
               clients: int = 1, *, client_rate: float = 0.08,
               pod_rate: float = 0.01, heartbeat_rate: float = 0.02,
               straggler_rate: float = 0.03, nan_rate: float = 0.0,
               recover_after: int = 3) -> "FaultInjector":
        """Generate a schedule from a seed -- a pure function of the
        arguments (``np.random.default_rng(seed)``; same seed => same
        schedule, different seeds diverge)."""
        rng = np.random.default_rng(seed)
        events: list[ChaosEvent] = []

        def target():
            return (int(rng.integers(pods)), int(rng.integers(devices)),
                    int(rng.integers(clients)))

        for s in range(steps):
            u = rng.random(5)
            if u[4] < nan_rate:
                events.append(ChaosEvent(s, "nan"))
            if u[0] < client_rate:
                p, d, c = target()
                events.append(ChaosEvent(s, "client", p, d, c))
                if s + recover_after < steps:
                    events.append(
                        ChaosEvent(s + recover_after, "recover", p, d, c))
            if u[1] < pod_rate and pods > 1:
                p = int(rng.integers(pods))
                events.append(ChaosEvent(s, "pod", p))
                if s + recover_after < steps:
                    events.append(ChaosEvent(s + recover_after, "recover", p))
            if u[2] < heartbeat_rate:
                p, d, _ = target()
                events.append(ChaosEvent(s, "heartbeat", p, d))
                if s + recover_after < steps:
                    events.append(
                        ChaosEvent(s + recover_after, "recover", p, d))
            if u[3] < straggler_rate:
                p, d, c = target()
                events.append(ChaosEvent(s, "straggler", p, d, c))
                if s + 2 * recover_after < steps:
                    events.append(ChaosEvent(s + 2 * recover_after,
                                             "recover", p, d, c))
        return cls(events)


def apply_event(member: Membership, ev: ChaosEvent, now: float = 0.0):
    """Apply one event to a live Membership (``nan`` is a driver-level
    signal and leaves membership untouched)."""
    if ev.kind in ("client", "device", "pod"):
        member.mark_failed(ev.pod, ev.dev, ev.client)
    elif ev.kind == "straggler":
        member.demote(ev.pod, ev.dev, ev.client)
    elif ev.kind == "heartbeat":
        # the target went silent while its live peers kept beating: age
        # the target's last heartbeat past the timeout and let the
        # sweep remove it (exercises the timeout path, target-local)
        member.last_seen[member.live] = now
        member.last_seen[member._idx(ev.pod, ev.dev, ev.client)] = (
            now - member.heartbeat_timeout - 1.0)
        member.sweep(now)
    elif ev.kind == "recover":
        member.restore(ev.pod, ev.dev, ev.client, now=now)
    elif ev.kind != "nan":
        raise ValueError(ev.kind)


def apply_events(member: Membership, events, now: float = 0.0):
    for ev in events:
        apply_event(member, ev, now)


def compile_schedule(injector: FaultInjector, member: Membership,
                     steps: int) -> list[MembershipArrays]:
    """ChaosSchedule -> per-step membership arrays.

    Replays the schedule against a deep copy of ``member`` (the caller's
    state is untouched) and returns ``arrays`` with ``arrays[s]`` =
    the ``(edge_weights, dev_weights, mask)`` the step function sees at
    step ``s`` -- i.e. after every event with ``ev.step <= s``.  A pure
    function of (schedule, membership config), so the oracle-side parity
    driver and a post-restore replay read identical arrays.
    """
    m = copy.deepcopy(member)
    arrays = []
    for s in range(steps):
        apply_events(m, injector.at(s), now=float(s))
        arrays.append(m.weights())
    return arrays


def replay_membership(injector: FaultInjector, member: Membership,
                      upto: int) -> Membership:
    """Membership state as of the START of step ``upto``: a fresh
    all-live copy with every event at steps ``< upto`` re-applied.  The
    driver calls this after a checkpoint restore so the replayed steps
    see the same membership arrays as the first pass."""
    m = member.fresh()
    for s in range(upto):
        apply_events(m, injector.at(s), now=float(s))
    return m
