"""Abstract input specs (ShapeDtypeStruct + NamedSharding) per arch x shape.

These are the dry-run stand-ins: weak-type-correct, shardable, and never
allocated.  The same builders produce concrete-batch shapes for the real
driver (launch/train.py) at reduced scale.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import flatbuf, hier, shardflat
from repro.core.topology import Topology
from repro.models.build import BuiltModel
from repro.models.config import LMConfig, ShapeCfg

PyTree = Any


def _sds(shape, dtype, topo, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=topo.sharding(spec))


def batch_axes(topo: Topology):
    """Spec entry sharding a serve batch dim over every data-parallel axis."""
    axes = tuple(a for a in (topo.pod_axis, topo.data_axis) if a)
    return axes if len(axes) > 1 else axes[0]


def train_batch_abstract(cfg: LMConfig, shape: ShapeCfg, topo: Topology):
    """{'train': {...[P, D, b_local, ...]}} abstract batch."""
    pd = topo.pods * topo.devices_per_pod
    assert shape.global_batch % pd == 0, (shape.global_batch, pd)
    b = shape.global_batch // pd
    sp = lambda *rest: topo.dev_spec(*rest)
    batch = {"tokens": _sds((topo.pods, topo.devices_per_pod, b,
                             shape.seq_len), jnp.int32, topo, sp(None, None))}
    if cfg.family in ("encdec", "audio"):
        batch["frames"] = _sds(
            (topo.pods, topo.devices_per_pod, b, cfg.encoder_frames,
             cfg.frontend_dim), jnp.float32, topo, sp(None, None, None))
    if cfg.n_patches:
        batch["patches"] = _sds(
            (topo.pods, topo.devices_per_pod, b, cfg.n_patches, cfg.d_model),
            jnp.float32, topo, sp(None, None, None))
    return {"train": batch}


def weights_abstract(topo: Topology, clients=None):
    """(edge_weights, dev_weights, mask) abstract runtime inputs --
    the arrays ``runtime.elastic.Membership.weights()`` emits.  With an
    active ClientConfig the mask is client-granular [P, D, K]."""
    ew = _sds((topo.pods,), jnp.float32, topo, P())
    dw = _sds((topo.pods, topo.devices_per_pod), jnp.float32, topo, P())
    if clients is not None and clients.active:
        mask = _sds((topo.pods, topo.devices_per_pod, clients.count),
                    jnp.float32, topo, P())
    else:
        mask = dw
    return ew, dw, mask


def train_state_abstract(built: BuiltModel, topo: Topology,
                         algo: hier.AlgoConfig):
    """Abstract TrainState with shardings applied.

    Mirrors ``algo.state_layout``: under ``"flat"`` the params / delta /
    EF / momentum entries come back as ``flatbuf.FlatState`` nodes whose
    single [P(, D), n_pad] buffer leaf carries the sharding (the layout
    rides through ``eval_shape`` in the treedef aux data)."""
    init_fn, _ = hier.make_hier_step(topo, algo, built.bundle)
    params_abs = built.abstract_params()
    state_abs = jax.eval_shape(init_fn, params_abs, jax.random.PRNGKey(0))
    shardings = hier.state_shardings(topo, algo, built.bundle, state_abs)
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        state_abs, shardings)


def serve_param_shardings(built: BuiltModel, topo: Topology, params=None):
    """Serve params: compute layout when weights are resident (fit per
    chip in bf16); FSDP master layout (data-sharded, per-layer gathers)
    otherwise.

    ``params`` may be a ``flatbuf.FlatState`` (a flat-state checkpoint
    served as-is): the sharding is then for the single buffer leaf --
    model-axis sharded on its last dim when the layout is sharded,
    replicated otherwise -- and the per-leaf serve views are taken with
    :func:`serve_params_from_flat`."""
    if isinstance(params, flatbuf.FlatState):
        ax = topo.model_axis if params.layout.shards > 1 else None
        spec = P(*([None] * params.batch_dims), ax)
        return jax.tree.map(lambda _: topo.sharding(spec), params)
    specs = (built.bundle.compute_specs
             if built.serve_layout == "resident"
             else built.bundle.master_specs)
    return jax.tree.map(
        lambda _, s: topo.sharding(P(*s)),
        built.abstract_params(), specs)


def serve_params_from_flat(built: BuiltModel, topo: Topology,
                           fs: flatbuf.FlatState, dtype=None):
    """Flat-state checkpoint -> serve param tree, zero-copy.

    ``fs`` may carry the training state's leading pod dim ([P, n_pad]);
    serving uses edge model 0 (post-round edge models are equal after
    cloud aggregation).  The returned tree is slice views of the buffer
    -- for a sharded layout the views are taken inside shard_map
    (``shardflat.tree_views``), so sharded leaves come back model-axis
    sharded and nothing is assembled or gathered; uneven (padded-shard)
    leaves are sliced to their LOGICAL extent, the don't-care zero tail
    never reaches the served tree.  Cast to ``dtype`` only when one is
    given (the cast is then the only copy).
    """
    if fs.batch_dims:
        fs = flatbuf.FlatState(fs.buf[(0,) * fs.batch_dims], fs.layout,
                               batch_dims=0)
    tree = shardflat.tree_views(topo, fs)
    if dtype is None:
        return tree
    return jax.tree.map(
        lambda v: v.astype(dtype)
        if jnp.issubdtype(v.dtype, jnp.floating) else v, tree)


def serve_params_abstract(built: BuiltModel, topo: Topology,
                          dtype=jnp.bfloat16):
    shard = serve_param_shardings(built, topo)
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(
            a.shape, dtype if jnp.issubdtype(a.dtype, jnp.floating)
            else a.dtype, sharding=s),
        built.abstract_params(), shard)


def prefill_batch_abstract(cfg: LMConfig, shape: ShapeCfg, topo: Topology):
    ba = batch_axes(topo)
    b = shape.global_batch
    batch = {"tokens": _sds((b, shape.seq_len), jnp.int32, topo,
                            P(ba if b > 1 else None, None))}
    if cfg.family in ("encdec", "audio"):
        batch["frames"] = _sds((b, cfg.encoder_frames, cfg.frontend_dim),
                               jnp.float32, topo,
                               P(ba if b > 1 else None, None, None))
    if cfg.n_patches:
        batch["patches"] = _sds((b, cfg.n_patches, cfg.d_model),
                                jnp.float32, topo,
                                P(ba if b > 1 else None, None, None))
    return batch


def decode_args_abstract(built: BuiltModel, shape: ShapeCfg,
                         topo: Topology):
    """(cache_abs, tokens_abs) for decode_step at this shape."""
    cfg = built.cfg
    b = shape.global_batch
    ba = batch_axes(topo) if b > 1 else None
    len_axis = topo.data_axis if b == 1 else None   # long_500k layout
    cache_abs = jax.eval_shape(
        functools.partial(built.make_cache, b, shape.seq_len, jnp.bfloat16))
    cspec = built.cache_specs(ba, len_axis)
    cache_abs = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                          sharding=topo.sharding(s)),
        cache_abs, cspec)
    tokens = _sds((b, 1), jnp.int32, topo, P(ba, None))
    return cache_abs, tokens
