"""Flat-buffer bucketization: layout invariants + roundtrip properties
(unsharded and model-axis-sharded per-shard-bucket layouts)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core import flatbuf, signs


def _tree_from_sizes(sizes, batch=(), dtype=jnp.float32, seed=0):
    key = jax.random.PRNGKey(seed)
    tree = {}
    for i, n in enumerate(sizes):
        shape = batch + ((n,) if n % 2 else (max(n // 2, 1), 2))
        tree[f"leaf{i}"] = jax.random.normal(
            jax.random.fold_in(key, i), shape, dtype)
    return tree


def test_layout_invariants():
    tree = _tree_from_sizes([33, 64, 7, 4096, 1], batch=(2, 3))
    lay = flatbuf.make_layout(tree, batch_dims=2)
    assert lay.n == 33 + 64 + 7 + 4096 + 1
    assert lay.n_pad % flatbuf.TILE == 0
    assert lay.n_pad >= lay.n
    offset = 0
    for slot in lay.slots:
        assert slot.offset == offset            # contiguous placement
        assert slot.offset % flatbuf.PACK == 0  # word-aligned
        assert slot.padded % flatbuf.PACK == 0
        assert slot.padded >= slot.size
        assert slot.word_offset * flatbuf.PACK == slot.offset
        offset += slot.padded
    assert lay.n_words * flatbuf.PACK == lay.n_pad


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(1, 300), min_size=1, max_size=6),
       st.integers(0, 2))
def test_roundtrip_property(sizes, batch_dims):
    batch = (2, 3)[:batch_dims]
    tree = _tree_from_sizes(sizes, batch=batch)
    lay = flatbuf.make_layout(tree, batch_dims=batch_dims)
    buf = flatbuf.flatten_tree(lay, tree, batch_dims=batch_dims)
    assert buf.shape == batch + (lay.n_pad,)
    back = flatbuf.unflatten_tree(lay, buf, batch_dims=batch_dims)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
        assert back[k].dtype == tree[k].dtype


def test_dtype_promotion_roundtrip_exact():
    """bf16 -> f32 promotion is widening: roundtrip is bit-exact."""
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (5, 33),
                                   jnp.bfloat16),
            "b": jax.random.normal(jax.random.PRNGKey(1), (64,),
                                   jnp.float32)}
    lay = flatbuf.make_layout(tree)
    assert lay.dtype == jnp.float32
    back = flatbuf.unflatten_tree(lay, flatbuf.flatten_tree(lay, tree))
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(1, 200), min_size=1, max_size=5),
       st.integers(0, 2 ** 31 - 1))
def test_pack_tree_equals_pack_of_flat(sizes, seed):
    """Word-level concat == pack of the float flat buffer, bitwise."""
    tree = _tree_from_sizes(sizes, batch=(2, 3), seed=seed % 1000)
    lay = flatbuf.make_layout(tree, batch_dims=2)
    words = flatbuf.pack_tree(lay, tree, batch_dims=2)
    buf = flatbuf.flatten_tree(lay, tree, batch_dims=2)
    expect = signs.pack_signs(signs.sgn(buf))
    assert words.shape == (2, 3, lay.n_words)
    np.testing.assert_array_equal(np.asarray(words), np.asarray(expect))


def test_pack_tree_fuses_dc_correction():
    tree = _tree_from_sizes([100, 33], batch=(2, 4), seed=5)
    delta = {k: jax.random.normal(jax.random.PRNGKey(9),
                                  (2,) + v.shape[2:], v.dtype)
             for k, v in tree.items()}
    lay = flatbuf.make_layout(tree, batch_dims=2)
    words = flatbuf.pack_tree(lay, tree, batch_dims=2, delta=delta,
                              rho=0.7, delta_batch_dims=1)
    corrected = jax.tree.map(
        lambda u, dl: u + 0.7 * dl[:, None].astype(u.dtype), tree, delta)
    expect = flatbuf.pack_tree(lay, corrected, batch_dims=2)
    np.testing.assert_array_equal(np.asarray(words), np.asarray(expect))


DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)


def _edge_tree(sizes, dtype_idxs, batch=(), seed=0):
    """Leaves covering the edge cases: size 0 -> zero-size leaf, size 1
    -> scalar leaf, else a vector; dtypes cycle through DTYPES."""
    key = jax.random.PRNGKey(seed)
    tree = {}
    for i, n in enumerate(sizes):
        dt = DTYPES[dtype_idxs[i % len(dtype_idxs)] % len(DTYPES)]
        shape = (0, 3) if n == 0 else (() if n == 1 else (n,))
        tree[f"leaf{i}"] = jax.random.normal(
            jax.random.fold_in(key, i), batch + shape, dt)
    return tree


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 80), min_size=1, max_size=5),
       st.lists(st.integers(0, 2), min_size=1, max_size=5),
       st.integers(0, 3))
def test_roundtrip_edge_cases(sizes, dtype_idxs, batch_dims):
    """Mixed dtypes + scalar leaves + zero-size leaves + up to 3 batch
    dims: flatten/unflatten restores every leaf bit-exactly, and
    pack_tree still matches pack-of-flat (so slot offsets stay aligned
    even across empty slots)."""
    batch = (2, 2, 3)[:batch_dims]
    tree = _edge_tree(sizes, dtype_idxs, batch=batch)
    lay = flatbuf.make_layout(tree, batch_dims=batch_dims)
    assert lay.n == sum(0 if n == 0 else max(n, 1) for n in sizes)
    buf = flatbuf.flatten_tree(lay, tree, batch_dims=batch_dims)
    assert buf.shape == batch + (lay.n_pad,)
    back = flatbuf.unflatten_tree(lay, buf, batch_dims=batch_dims)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        assert back[k].shape == tree[k].shape
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
    words = flatbuf.pack_tree(lay, tree, batch_dims=batch_dims)
    expect = signs.pack_signs(signs.sgn(buf))
    np.testing.assert_array_equal(np.asarray(words), np.asarray(expect))


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(1, 50), min_size=2, max_size=6),
       st.integers(0, 2 ** 31 - 1))
def test_layout_stable_under_tree_ordering(sizes, seed):
    """jax.tree canonicalizes dict key order, so the layout -- and hence
    every persisted flat buffer -- must not depend on insertion order."""
    tree = _edge_tree(sizes, [0], seed=seed % 1000)
    rev = {k: tree[k] for k in reversed(list(tree))}
    l1 = flatbuf.make_layout(tree)
    l2 = flatbuf.make_layout(rev)
    assert l1.slots == l2.slots
    assert l1.treedef == l2.treedef
    b1 = flatbuf.flatten_tree(l1, tree)
    b2 = flatbuf.flatten_tree(l2, rev)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    back = flatbuf.unflatten_tree(l1, b2)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


def test_flat_state_pytree_node():
    """FlatState round-trips through jax.tree transforms with the layout
    riding in the treedef (same layout -> same structure)."""
    tree = _edge_tree([33, 7], [0, 1])
    fs = flatbuf.from_tree(tree)
    mapped = jax.tree.map(lambda x: x * 2, fs)
    assert isinstance(mapped, flatbuf.FlatState)
    assert mapped.layout is fs.layout
    leaves, treedef = jax.tree.flatten(fs)
    assert len(leaves) == 1
    assert treedef == jax.tree.flatten(mapped)[1]
    back = fs.tree()
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


def _shard_specs(tree, batch_dims):
    """Model axis on every leaf's first post-batch dim (where one
    exists): nonzero dims shard -- uneven extents as zero-padded blocks
    (shard_pad) -- while zero-size dims and scalar leaves must fall
    back to per-bucket copies."""
    return {k: (P("model", *([None] * (v.ndim - batch_dims - 1)))
                if v.ndim > batch_dims else P())
            for k, v in tree.items()}


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 80), min_size=1, max_size=5),
       st.lists(st.integers(0, 2), min_size=1, max_size=5),
       st.sampled_from([1, 2, 4]),
       st.integers(0, 2))
def test_sharded_roundtrip(sizes, dtype_idxs, shards, batch_dims):
    """Sharded layouts (shard counts 1/2/4, mixed dtypes, UNEVEN dims
    drawn as sharded padded-block slots, scalar and zero-size leaves):
    flatten/unflatten restores every leaf bit-exactly, pack matches
    pack-of-flat wordwise, and the bucket geometry invariants hold."""
    batch = (2, 3)[:batch_dims]
    tree = _edge_tree(sizes, dtype_idxs, batch=batch)
    specs = _shard_specs(tree, batch_dims)
    sharding = flatbuf.ModelSharding(shards, "model", specs)
    lay = flatbuf.make_layout(tree, batch_dims=batch_dims,
                              sharding=sharding)
    base = flatbuf.make_layout(tree, batch_dims=batch_dims)
    assert lay.shards in (1, shards)
    assert lay.n == base.n                  # pads/copies: no new coords
    assert lay.n_pad == lay.shards * lay.bucket_pad
    assert lay.bucket_pad % flatbuf.TILE == 0
    offset = 0
    for slot, k in zip(lay.slots, sorted(tree)):  # per-BUCKET placement
        assert slot.offset == offset
        assert slot.offset % flatbuf.PACK == 0
        leaf_shape = tuple(tree[k].shape[batch_dims:])
        assert slot.global_shape(lay.shards) == leaf_shape
        if lay.shards > 1 and len(leaf_shape) and leaf_shape[0] > 0:
            # every nonzero spec'd dim stays SHARDED -- never a copy
            assert slot.shard_dim == 0
            ext = leaf_shape[0]
            blk = -(-ext // lay.shards)
            assert slot.shape[0] == blk
            assert slot.shard_pad == blk * lay.shards - ext
        else:
            assert slot.shard_dim is None and slot.shard_pad == 0
        offset += slot.padded

    buf = flatbuf.flatten_tree(lay, tree, batch_dims=batch_dims)
    assert buf.shape == batch + (lay.n_pad,)
    back = flatbuf.unflatten_tree(lay, buf, batch_dims=batch_dims)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        assert back[k].shape == tree[k].shape
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
    words = flatbuf.pack_tree(lay, tree, batch_dims=batch_dims)
    expect = signs.pack_signs(signs.sgn(buf))
    np.testing.assert_array_equal(np.asarray(words), np.asarray(expect))


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(1, 60), min_size=2, max_size=4),
       st.integers(0, 2 ** 31 - 1))
def test_sharded_copies_and_blocks_land_in_buckets(sizes, seed):
    """Bucket m holds block m of every sharded leaf and a full copy of
    every unsharded leaf -- checked against bucket_trees + the bucket()
    sub-layout, which is what each shard_map rank computes locally."""
    # _tree_from_sizes gives even sizes shape (n/2, 2): x4 keeps the
    # sharded dim0 = 2s divisible by 2 for every leaf
    sizes = [s * 4 for s in sizes]
    tree = _tree_from_sizes(sizes, seed=seed % 1000)
    specs = _shard_specs(tree, 0)
    lay = flatbuf.make_layout(
        tree, sharding=flatbuf.ModelSharding(2, "model", specs))
    assert lay.shards == 2
    buf = flatbuf.flatten_tree(lay, tree)
    bp = lay.bucket_pad
    bucket = lay.bucket()
    for m, local_tree in enumerate(flatbuf.bucket_trees(lay, tree)):
        local = flatbuf.flatten_tree(bucket, local_tree)
        np.testing.assert_array_equal(
            np.asarray(buf[m * bp:(m + 1) * bp]), np.asarray(local))


def test_uneven_dims_shard_and_normalization_needs_no_shardable_leaf():
    """Uneven extents now SHARD (padded blocks) instead of collapsing
    the layout; only a sharding under which no leaf can shard at all
    (scalars, zero-size dims) normalizes back to shards=1 -- callers
    can still pass the mesh sharding unconditionally."""
    tree = {"a": jnp.zeros((33,)), "s": jnp.zeros(())}
    lay = flatbuf.make_layout(tree, sharding=flatbuf.ModelSharding(
        2, "model", _shard_specs(tree, 0)))
    assert lay.shards == 2                   # 33 shards as 17+17 (pad 1)
    a = lay.slots[0]
    assert (a.shard_dim, a.shape, a.shard_pad) == (0, (17,), 1)
    assert a.global_shape(2) == (33,)
    empty = {"z": jnp.zeros((0, 3)), "s": jnp.zeros(())}
    lay0 = flatbuf.make_layout(empty, sharding=flatbuf.ModelSharding(
        2, "model", _shard_specs(empty, 0)))
    assert lay0.shards == 1
    assert lay0 == flatbuf.make_layout(empty)


def test_uneven_sharded_blocks_zero_tail_and_bucket_trees():
    """Padded-shard geometry end to end: bucket m of an uneven leaf is
    block m of the zero-extended leaf (don't-care tail), the reference
    flatten/pack place it at the bucket offsets, and unflatten drops
    the tail exactly."""
    tree = {"a": jnp.arange(1, 6, dtype=jnp.float32),       # 5 over 2
            "b": jnp.arange(1, 8, dtype=jnp.float32)}       # 7 over 2
    lay = flatbuf.make_layout(tree, sharding=flatbuf.ModelSharding(
        2, "model", _shard_specs(tree, 0)))
    assert [(s.shape, s.shard_pad) for s in lay.slots] == [
        ((3,), 1), ((4,), 1)]
    assert lay.n == 5 + 7                    # pads are not real coords
    bts = flatbuf.bucket_trees(lay, tree)
    np.testing.assert_array_equal(np.asarray(bts[0]["a"]), [1, 2, 3])
    np.testing.assert_array_equal(np.asarray(bts[1]["a"]), [4, 5, 0.0])
    np.testing.assert_array_equal(np.asarray(bts[1]["b"]), [5, 6, 7, 0.0])
    buf = flatbuf.flatten_tree(lay, tree)
    bp = lay.bucket_pad
    # bucket 1 holds the tail blocks at the same slot offsets
    np.testing.assert_array_equal(np.asarray(buf[bp:bp + 3]), [4, 5, 0.0])
    back = flatbuf.unflatten_tree(lay, buf)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
    words = flatbuf.pack_tree(lay, tree)
    expect = signs.pack_signs(signs.sgn(buf))
    np.testing.assert_array_equal(np.asarray(words), np.asarray(expect))
    # pad_tree/unpad_tree are the shard_map boundary forms
    pt = flatbuf.pad_tree(lay, tree)
    assert pt["a"].shape == (6,) and pt["b"].shape == (8,)
    np.testing.assert_array_equal(np.asarray(pt["a"]), [1, 2, 3, 4, 5, 0])
    ut = flatbuf.unpad_tree(lay, pt)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(ut[k]),
                                      np.asarray(tree[k]))


def test_copy_fallback_warns_once_per_leaf_path():
    """The zero-size-dim copy fallback warns keyed on the LEAF PATH:
    two different leaves of the same shape each warn, re-laying the
    same tree out does not re-warn, and uneven sharded leaves do not
    warn at all (they are first-class now)."""
    import warnings as _w
    tree = {"za": jnp.zeros((0, 3)), "zb": jnp.zeros((0, 3)),
            "odd": jnp.zeros((5,))}
    sharding = flatbuf.ModelSharding(2, "model", _shard_specs(tree, 0))
    with _w.catch_warnings(record=True) as rec:
        _w.simplefilter("always")
        flatbuf.make_layout(tree, sharding=sharding)
    msgs = [str(r.message) for r in rec]
    assert sum("'za'" in m for m in msgs) == 1
    assert sum("'zb'" in m for m in msgs) == 1      # same shape, own warn
    assert not any("odd" in m for m in msgs)        # uneven: no fallback
    assert all("zero-size" in m for m in msgs)
    with _w.catch_warnings(record=True) as rec2:
        _w.simplefilter("always")
        flatbuf.make_layout(tree, sharding=sharding)  # same paths: deduped
    assert not rec2


def test_sharded_from_tree_and_with_dtype():
    tree = _tree_from_sizes([64, 128])
    fs = flatbuf.from_tree(tree, sharding=flatbuf.ModelSharding(
        4, "model", _shard_specs(tree, 0)))
    assert fs.layout.shards == 4
    relabeled = flatbuf.with_dtype(fs.layout, jnp.bfloat16)
    assert relabeled.shards == 4
    assert relabeled.bucket_pad == fs.layout.bucket_pad
    back = fs.tree()
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


def test_rejects_unsupported_leaves():
    with pytest.raises(ValueError):
        flatbuf.make_layout({"u": jnp.zeros((4,), jnp.uint32)})
    with pytest.raises(ValueError):
        flatbuf.make_layout({})
    with pytest.raises(ValueError):  # non-widening promotion (int+bf16)
        flatbuf.make_layout({"i": jnp.zeros((4,), jnp.int32),
                             "f": jnp.zeros((4,), jnp.bfloat16)})
    flatbuf.make_layout({"s": jnp.zeros((4,), jnp.int8)})  # all-int OK
