"""Virtual-client scale-out: K federated clients per mesh data slice.

The paper targets fleets where each edge server fronts *many* devices
with unequal data shares ``|D_qk|`` and intermittent availability; a
mesh has a fixed number of physical ``data`` slices.  This module maps
``K`` virtual clients onto every (pod, data) slice:

  * **batch carving** -- a device batch ``[P, D, b, ...]`` is carved
    into ``K`` per-client shards and the client dim is merged into the
    voter axis: ``[P, D*K, b/K, ...]``.  Virtual client ``c`` of
    physical slice ``d`` is voter ``d*K + c``; the merged axis shards
    over ``data`` exactly like the physical one (each slice holds its
    own K clients), so carving is a local reshape -- no communication.
    The data layer can make the K shards genuinely distinct
    distributions (``alpha_client`` intra-edge skew in
    ``data.synthetic`` / ``data.emnist_like``), and a server-side edge
    assignment (``data.cluster``) regroups clients across the fleet by
    permuting exactly these row blocks (:func:`regroup_clients`).
  * **participation sampling** -- per-round client masks (Bernoulli or
    fixed-size), drawn from a scheme pinned to ``(seed, round)`` only.
  * **data-share weights** -- integer ``|D_qk|`` flow into the edge
    majority vote, which becomes a *weighted popcount*: the tally range
    is ``sum(w)`` rather than the voter count ``D`` (transports widen
    their tally dtypes accordingly, see ``core.votes``), masked-out
    clients contribute zero tally, and an edge whose quorum is empty
    abstains entirely (vote 0: ``v_q`` is left unchanged).

Pinned sampling scheme (the checkpoint contract): the participation
mask of global round ``t`` is a pure function of ``(seed, t)`` via a
counter-based elementwise hash,

    word(q, d, c) = splitmix32(index ^ splitmix32(seed ^ splitmix32(t)))

(plain uint32 arithmetic over a global client-index iota), NOT
``jax.random``: threefry is not partition-stable in this jax version
(``jax_threefry_partitionable=False``), so a sharded train step would
draw a different quorum than the eager oracle.  The hash is bitwise
identical under any GSPMD partitioning, eager or jit, independent of
transport, state layout, mesh shape and the step within the round --
restoring a checkpoint mid-round resamples the identical mask, and
every transport/state-layout combination sees the same quorum (the
derivation is pinned against a numpy reimplementation in
``tests/test_ref_fed_participation.py``).

``ClientConfig()`` (the default) is *inactive*: ``core.hier`` then runs
the exact pre-virtual-client code path, so ``K=1`` / full participation
/ unit weights is bitwise identical to the legacy trajectory.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

PARTICIPATION_MODES = ("full", "bernoulli", "fixed")
CLIENT_MODES = ("merged", "stream")


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    """Static virtual-client configuration (closed over, never traced).

    count          -- K virtual clients per physical data slice.
    participation  -- per-round sampling of the voting quorum:
                      ``full``      every client votes every round;
                      ``bernoulli`` each client votes i.i.d. with
                                    probability ``rate``;
                      ``fixed``     exactly ``max(1, round(rate*D*K))``
                                    clients per edge vote (uniformly,
                                    without replacement).
    rate           -- target participation fraction (ignored by
                      ``full``).
    seed           -- base key of the pinned per-round sampling scheme.
    weights        -- optional integer data shares ``|D_qk|`` as nested
                      tuples ``[pods][devices][count]`` (static, so
                      tally-dtype promotion can be decided at trace
                      time); ``None`` means unit weights.
    mode           -- how the K clients execute inside the train step:
                      ``merged``  (default) the client dim merges into
                                  the voter axis ([P, D*K, b/K, ...]) --
                                  every client's sign plane is live at
                                  once, memory O(K * model);
                      ``stream``  clients loop inside the step: each
                                  client's signs are packed and folded
                                  into a persistent weighted tally
                                  buffer, memory O(model/32 + tally).
                      Bitwise-identical trajectories (``stream`` is
                      asserted against ``merged`` on the parity matrix).
    """
    count: int = 1
    participation: str = "full"
    rate: float = 1.0
    seed: int = 0
    weights: tuple | None = None
    mode: str = "merged"

    def __post_init__(self):
        if self.count < 1:
            raise ValueError(f"clients per device must be >= 1: {self.count}")
        if self.participation not in PARTICIPATION_MODES:
            raise ValueError(f"unknown participation {self.participation!r}")
        if self.mode not in CLIENT_MODES:
            raise ValueError(f"unknown client mode {self.mode!r}")
        if not 0.0 < self.rate <= 1.0:
            raise ValueError(f"participation rate must be in (0, 1]: "
                             f"{self.rate}")
        if self.weights is not None:
            flat = [w for q in self.weights for d in q for w in d]
            if not flat or any(int(w) != w or w < 0 for w in flat):
                raise ValueError("client weights must be nonnegative "
                                 f"integers |D_qk|: {self.weights!r}")

    @property
    def active(self) -> bool:
        """Whether the virtual-client machinery engages at all; the
        inactive default keeps ``core.hier`` on the legacy path."""
        return (self.count > 1 or self.participation != "full"
                or self.weights is not None)

    def weight_array(self, pods: int, devices: int) -> np.ndarray:
        """[P, D, K] int32 data shares (ones when ``weights is None``)."""
        if self.weights is None:
            return np.ones((pods, devices, self.count), np.int32)
        w = np.asarray(self.weights, np.int32)
        if w.shape != (pods, devices, self.count):
            raise ValueError(
                f"client weights shape {w.shape} != "
                f"{(pods, devices, self.count)} (pods, devices, count)")
        return w

    def weight_bound(self, pods: int, devices: int) -> int:
        """Static per-edge tally bound ``max_q sum_k |D_qk|`` -- the
        range of the weighted vote tally (picks the int tally dtype in
        ``votes.vote_ar_int8``; unit weights give the voter count)."""
        return int(self.weight_array(pods, devices).sum(axis=(1, 2)).max())


def _splitmix32(x: jax.Array) -> jax.Array:
    """Elementwise uint32 avalanche (the splitmix32 finalizer) -- the
    counter-based generator behind participation sampling.  Pure
    elementwise integer ops over a global iota, so the drawn bits are
    BITWISE identical under any GSPMD partitioning, jit or eager
    (``jax.random``'s threefry is not partition-stable here: a sharded
    train step would draw a different quorum than the oracle)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def _client_words(cfg: ClientConfig, pods: int, devices: int,
                  round_index) -> jax.Array:
    """[P, D, K] uint32 hash words of round ``t`` (the pinned scheme of
    the module docstring)."""
    n = pods * devices * cfg.count
    idx = jnp.arange(n, dtype=jnp.uint32).reshape(
        pods, devices, cfg.count)
    rnd = jnp.asarray(round_index).astype(jnp.uint32)
    base = _splitmix32(jnp.uint32(cfg.seed) ^ _splitmix32(rnd))
    return _splitmix32(idx ^ base)


def participation_mask(cfg: ClientConfig, pods: int, devices: int,
                       round_index) -> jax.Array:
    """[P, D, K] float {0,1} participation mask of global round ``t``.

    A pure function of ``(cfg.seed, round_index)`` via the pinned
    counter-hash scheme documented in the module docstring;
    ``round_index`` may be a traced integer (``step // t_e`` inside the
    train step), and the drawn mask is bitwise identical eager / jit /
    sharded.
    """
    shape = (pods, devices, cfg.count)
    if cfg.participation == "full":
        return jnp.ones(shape, jnp.float32)
    words = _client_words(cfg, pods, devices, round_index)
    if cfg.participation == "bernoulli":
        # top 24 hash bits as a uniform in [0, 2^24): exact threshold
        thresh = jnp.uint32(int(round(cfg.rate * (1 << 24))))
        return ((words >> 8) < thresh).astype(jnp.float32)
    # fixed-size: exactly m of the edge's D*K clients vote -- the m
    # smallest hash words (stable argsort: hash collisions break by
    # client index, still deterministic)
    n = devices * cfg.count
    m = max(1, int(round(cfg.rate * n)))
    w = words.reshape(pods, n)
    ranks = jnp.argsort(jnp.argsort(w, axis=1), axis=1)
    return (ranks < m).astype(jnp.float32).reshape(shape)


def carve_batch(batch, count: int):
    """Carve [P, D, b, ...] device batches into per-client shards and
    merge the client dim into the voter axis: [P, D*K, b/K, ...].

    Client ``c`` of slice ``d`` (voter ``d*K + c``) owns rows
    ``[c*b/K, (c+1)*b/K)`` of the slice batch; the reshape is local
    under a ``(pod, data, ...)`` sharding.  ``count=1`` is the
    identity (no reshape is emitted at all)."""
    if count == 1:
        return batch

    def carve(x):
        p, d, b = x.shape[:3]
        if b % count:
            raise ValueError(
                f"per-device batch {b} does not divide into "
                f"{count} virtual clients")
        return x.reshape((p, d * count, b // count) + x.shape[3:])

    return jax.tree.map(carve, batch)


def regroup_clients(batch, assignment, count: int):
    """Apply a server-side edge assignment (``data.cluster``) to
    [P, D, b, ...] device batches by permuting per-client row blocks
    across the fleet.

    ``assignment[s]`` is the ORIGINAL flat client index -- voter order,
    client c of slice d of pod q is ``(q*D + d)*K + c`` -- that occupies
    flat slot ``s`` after regrouping (the output of
    ``data.cluster.assignment_order``).  The permutation moves exactly
    the row blocks :func:`carve_batch` hands to each voter, so a
    clustered/random regrouping composes with the carve with no other
    change: voter ``s`` simply sees its newly-assigned client's rows.
    ``assignment=None`` is the identity.  The oracle-side counterpart
    regrouping nested per-client lists is
    ``core.ref_fed.regroup_client_data`` (the two are pinned against
    each other by the clustered parity cells)."""
    if assignment is None:
        return batch
    idx = np.asarray(assignment, int)

    def move(x):
        p, d, b = x.shape[:3]
        if b % count:
            raise ValueError(
                f"per-device batch {b} does not divide into "
                f"{count} virtual clients")
        if len(idx) != p * d * count:
            raise ValueError(
                f"assignment permutes {len(idx)} clients; batch has "
                f"{p * d * count}")
        flat = x.reshape((p * d * count, b // count) + x.shape[3:])
        return flat[idx].reshape(x.shape)

    return jax.tree.map(move, batch)


def validate_batch_carve(batch_per_device: int, count: int,
                         flag: str = "clients_per_device") -> None:
    """Early (CLI-level) form of :func:`carve_batch`'s divisibility
    check: raise a clean ``ValueError`` before any tracing happens, so
    launchers can reject a bad ``--clients_per_device`` with a readable
    message instead of a mid-trace shape error."""
    if count > 1 and batch_per_device % count:
        raise ValueError(
            f"per-device batch {batch_per_device} does not divide into "
            f"{count} virtual clients (--{flag})")


def client_slice(batch, count: int, c):
    """Client ``c``'s shard of an *uncarved* [P, D, b, ...] batch.

    The streamed sweep's counterpart of :func:`carve_batch`: client
    ``c`` of slice ``d`` owns rows ``[c*b/K, (c+1)*b/K)`` -- exactly the
    rows voter ``d*K + c`` sees after the merged reshape -- but only ONE
    client's [P, D, b/K, ...] shard is ever materialized (``c`` may be a
    traced loop index; the slice is a ``dynamic_slice`` on the batch-row
    dim, no [P, D*K, ...] reshape)."""
    if count == 1:
        return batch

    def take(x):
        b = x.shape[2]
        if b % count:
            raise ValueError(
                f"per-device batch {b} does not divide into "
                f"{count} virtual clients")
        rows = b // count
        return jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(c, jnp.int32) * rows, rows, axis=2)

    return jax.tree.map(take, batch)


def participating_shares(dev_weights: jax.Array, weights: jax.Array,
                         maskf: jax.Array) -> jax.Array:
    """Per-edge aggregation shares of the *participating* clients.

    dev_weights: [P, D] physical-slice weighting from the caller (the
    legacy ``|D_qk|/D_q``); weights: [P, D, K] float data shares;
    maskf: [P, D, K] float {0,1} participation.  Returns [P, D*K]
    shares ``w_qk m_qk / sum_j w_qj m_qj`` (zero when the whole edge is
    masked out) -- the anchor pass and the full-precision edge means
    reweight to exactly the participating data shares.
    """
    p, d, k = maskf.shape
    raw = (dev_weights[:, :, None] * weights * maskf).reshape(p, d * k)
    tot = jnp.sum(raw, axis=1, keepdims=True)
    return jnp.where(tot > 0, raw / jnp.where(tot > 0, tot, 1.0), 0.0)
