"""Fused flat-buffer transport: bit-identity with the per-leaf transports
at the votes level.

Full train-step trajectory parity (method x transport x state_layout x
regime, single- and multi-device) lives in tests/test_parity_matrix.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import hier, signs, votes
from repro.core.topology import single_device_topology


@pytest.fixture(scope="module")
def topo():
    return single_device_topology()


def _tree(seed=0, pd=(2, 5), dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(key, pd + (3, 33), dtype),
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   pd + (64,), dtype),
            "v": jax.random.normal(jax.random.fold_in(key, 2),
                                   pd + (7, 32), dtype)}


SPECS = {"w": P(None, None), "b": P(None), "v": P(None, None)}


@pytest.mark.parametrize("use_mask", [False, True])
def test_fused_vote_identical_to_per_leaf(topo, use_mask):
    tree = _tree()
    mask = None
    if use_mask:
        mask = jnp.asarray([[1, 1, 0, 1, 0], [1, 0, 0, 1, 1]],
                           jnp.float32) > 0.5
    vf = votes.fused_sign_vote(topo, tree, None, 0.0, mask)
    for k, leaf in tree.items():
        s = signs.sgn(leaf)
        v_ag = votes.majority_vote_dev(topo, s, mask, "ag_packed", SPECS[k])
        v_ar = votes.vote_ar_int8(topo, s, mask)
        assert vf[k].shape == leaf.shape[:1] + leaf.shape[2:]
        np.testing.assert_array_equal(np.asarray(vf[k]), np.asarray(v_ag))
        np.testing.assert_array_equal(np.asarray(vf[k]), np.asarray(v_ar))


def test_fused_vote_dc_folding(topo):
    """sgn(u + rho*delta) fused pre-sign == per-leaf corrected vote."""
    tree = _tree(seed=3)
    delta = {k: jax.random.normal(jax.random.PRNGKey(9),
                                  (2,) + v.shape[2:], v.dtype)
             for k, v in tree.items()}
    mask = jnp.asarray([[1, 1, 1, 0, 1], [1, 1, 1, 1, 1]]) > 0
    vf = votes.fused_sign_vote(topo, tree, delta, 0.3, mask)
    for k, leaf in tree.items():
        u = leaf + 0.3 * delta[k][:, None].astype(leaf.dtype)
        v_ag = votes.majority_vote_dev(topo, signs.sgn(u), mask,
                                       "ag_packed", SPECS[k])
        np.testing.assert_array_equal(np.asarray(vf[k]), np.asarray(v_ag))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_pallas_interpret_route_matches_jnp(topo, monkeypatch, dtype):
    """REPRO_FUSED_PALLAS=interpret drives the real kernels (interpret
    mode on CPU) through the same chain -- must match the jnp path
    bitwise, including bf16 trees (DC pre-added in leaf dtype: the
    kernel's f32 fold is only used for all-f32 trees)."""
    tree = _tree(seed=4, pd=(1, 4), dtype=dtype)
    delta = {k: jax.random.normal(jax.random.PRNGKey(8),
                                  (1,) + v.shape[2:], v.dtype)
             for k, v in tree.items()}
    mask = jnp.asarray([[1.0, 0.0, 1.0, 1.0]]) > 0.5
    v_jnp = votes.fused_sign_vote(topo, tree, delta, 0.5, mask)
    monkeypatch.setenv("REPRO_FUSED_PALLAS", "interpret")
    v_krn = votes.fused_sign_vote(topo, tree, delta, 0.5, mask)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(v_jnp[k]),
                                      np.asarray(v_krn[k]))


def test_fused_pallas_delta_slab_mapping(topo, monkeypatch):
    """Multi-tile buffer (rows not a power of two) with DC folded in the
    kernel: the per-voter delta re-read via the BlockSpec index map must
    match the jnp path for every (pod, device) slab."""
    key = jax.random.PRNGKey(11)
    # ~6 tiles of 4096 coords -> rows=6, row block 2, 3 blocks per slab
    tree = {"m": jax.random.normal(key, (2, 3, 24000)),
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   (2, 3, 500))}
    delta = {k: jax.random.normal(jax.random.fold_in(key, 2),
                                  (2,) + v.shape[2:], v.dtype)
             for k, v in tree.items()}
    v_jnp = votes.fused_sign_vote(topo, tree, delta, 0.4, None)
    monkeypatch.setenv("REPRO_FUSED_PALLAS", "interpret")
    v_krn = votes.fused_sign_vote(topo, tree, delta, 0.4, None)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(v_jnp[k]),
                                      np.asarray(v_krn[k]))


def test_per_leaf_fused_dispatch_falls_back(topo):
    """Per-leaf callers (FSDP lift) route 'fused' through ag_packed /
    ar_int8 -- identical votes either way."""
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 33))
    s = signs.sgn(x)
    out = votes.majority_vote_dev(topo, s, None, "fused", P(None))
    ref = signs.majority_vote(s[0], axis=0)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref))


def test_algo_config_validates_transport():
    with pytest.raises(ValueError):
        hier.AlgoConfig(transport="bogus")
    with pytest.raises(ValueError):
        hier.AlgoConfig(method="bogus")
    hier.AlgoConfig(transport="fused")          # accepted
