"""Vote transports must be bit-identical and correct at P=D=1 (single dev).

The multi-device equivalence (8 host CPUs, 2x2x2 mesh) runs in a
subprocess -- see test_distributed.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import signs, votes
from repro.core.topology import single_device_topology


@pytest.fixture(scope="module")
def topo():
    return single_device_topology()


@pytest.mark.parametrize("leaf_shape", [(64,), (3, 64), (5, 7, 32)])
def test_transports_identical(topo, leaf_shape):
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5) + leaf_shape)
    s = signs.sgn(x)
    v1 = votes.vote_ar_int8(topo, s, None)
    v2 = votes.vote_ag_packed(topo, s, None, P(*([None] * len(leaf_shape))))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    v3 = votes.fused_sign_vote(topo, {"leaf": s.astype(jnp.float32)})["leaf"]
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v3))
    # oracle per pod
    for p in range(2):
        ref = signs.majority_vote(s[p].reshape(5, -1), axis=0)
        np.testing.assert_array_equal(
            np.asarray(v1[p]).reshape(-1), np.asarray(ref))


def test_transports_mask(topo):
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 128))
    s = signs.sgn(x)
    mask = jnp.asarray([[1, 1, 0, 1, 0, 1]], jnp.float32) > 0
    v1 = votes.vote_ar_int8(topo, s, mask)
    v2 = votes.vote_ag_packed(topo, s, mask, P(None))
    v3 = votes.fused_sign_vote(topo, {"leaf": s.astype(jnp.float32)},
                               mask=mask)["leaf"]
    ref = signs.majority_vote(s[0][np.asarray(mask[0])], axis=0)
    np.testing.assert_array_equal(np.asarray(v1[0]), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(v2[0]), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(v3[0]), np.asarray(ref))


def test_ar_int8_upcasts_beyond_127_voters(topo):
    """Regression: D > 127 used to wrap the int8 tally (129 unanimous +1
    voters summed to -127 -> vote -1)."""
    s = jnp.ones((1, 129, 64), jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(votes.vote_ar_int8(topo, s, None)), 1)
    rng = np.random.default_rng(3)
    s = jnp.asarray(rng.choice([-1, 1], size=(2, 200, 33)), jnp.int8)
    ref = np.stack([np.asarray(signs.majority_vote(s[p], axis=0))
                    for p in range(2)])
    np.testing.assert_array_equal(
        np.asarray(votes.vote_ar_int8(topo, s, None)), ref)
    # masked: only 100 of 200 voters count, tally still exact
    mask = jnp.asarray(rng.integers(0, 2, (2, 200)), jnp.float32) > 0.5
    got = votes.vote_ar_int8(topo, s, mask)
    for p in range(2):
        ref_p = signs.majority_vote(s[p][np.asarray(mask[p])], axis=0)
        np.testing.assert_array_equal(np.asarray(got[p]), np.asarray(ref_p))


def test_weighted_vote_transports_identical(topo):
    """Integer |D_qk| vote weights: all three transports compute the
    same weighted popcount as the signs-level oracle, and an edge whose
    whole quorum carries weight 0 abstains (vote 0)."""
    rng = np.random.default_rng(11)
    s = jnp.asarray(rng.choice([-1, 1], size=(3, 5, 64)), jnp.int8)
    w = jnp.asarray(rng.integers(0, 6, (3, 5)), jnp.int32)
    w = w.at[2].set(0)                      # pod 2: empty quorum
    bound = int(np.max(np.sum(np.asarray(w), axis=1)))
    v1 = votes.vote_ar_int8(topo, s, w, weight_bound=bound)
    v2 = votes.vote_ag_packed(topo, s, w, P(None))
    v3 = votes.fused_sign_vote(topo, {"leaf": s.astype(jnp.float32)},
                               mask=w)["leaf"]
    for p in range(3):
        ref = signs.majority_vote(s[p], w[p], axis=0)
        np.testing.assert_array_equal(np.asarray(v1[p]), np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(v2[p]), np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(v3[p]), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(v1[2]), 0)


def test_weighted_tally_promotes_beyond_int8(topo):
    """Regression (boundary): the int tally promotes on sum(w), not on
    the voter count -- two voters of weight 64 are a 128-range tally
    that would wrap int8 (128 -> -128 -> vote -1)."""
    s = jnp.ones((1, 2, 64), jnp.int8)      # both vote +1
    w = jnp.asarray([[64, 64]], jnp.int32)  # sum(w) = 128 > 127
    np.testing.assert_array_equal(
        np.asarray(votes.vote_ar_int8(topo, s, w, weight_bound=128)), 1)
    # at the boundary sum(w) = 127 the tally still rides int8 exactly
    w127 = jnp.asarray([[64, 63]], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(votes.vote_ar_int8(topo, s, w127, weight_bound=127)), 1)
    assert votes._tally_acc(127) == jnp.int8
    assert votes._tally_acc(128) == jnp.int16
    assert votes._tally_acc(32768) == jnp.int32
    # integer weights WITHOUT a bound must fail loudly -- the
    # voter-count default would silently re-open the int8 wrap
    with pytest.raises(ValueError, match="weight_bound"):
        votes.vote_ar_int8(topo, s, w)
    # randomized: mixed signs, weights large enough to break int8
    rng = np.random.default_rng(5)
    s = jnp.asarray(rng.choice([-1, 1], size=(2, 9, 33)), jnp.int8)
    w = jnp.asarray(rng.integers(0, 40, (2, 9)), jnp.int32)
    bound = int(np.max(np.sum(np.asarray(w), axis=1)))
    got = votes.vote_ar_int8(topo, s, w, weight_bound=bound)
    for p in range(2):
        ref = signs.majority_vote(s[p], w[p], axis=0)
        np.testing.assert_array_equal(np.asarray(got[p]), np.asarray(ref))


def test_fused_vote_many_voters(topo):
    """D > 64 takes _popcount_vote_words's reduction branch (the voter
    unroll is capped) -- results must still match the oracle and the
    int-tally transport, masked and unmasked."""
    rng = np.random.default_rng(7)
    s = jnp.asarray(rng.choice([-1, 1], size=(2, 130, 96)), jnp.int8)
    tree = {"leaf": s.astype(jnp.float32)}
    got = votes.fused_sign_vote(topo, tree)["leaf"]
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(votes.vote_ar_int8(topo, s, None)))
    mask = jnp.asarray(rng.integers(0, 2, (2, 130)), jnp.float32) > 0.5
    got = votes.fused_sign_vote(topo, tree, mask=mask)["leaf"]
    for p in range(2):
        ref_p = signs.majority_vote(s[p][np.asarray(mask[p])], axis=0)
        np.testing.assert_array_equal(np.asarray(got[p]), np.asarray(ref_p))


def test_packed_dispatch_fallback(topo):
    """Leaves with minor dim % 32 != 0 fall back to int8 (same result)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 33))
    s = signs.sgn(x)
    out = votes.majority_vote_dev(topo, s, None, "ag_packed", P(None))
    ref = signs.majority_vote(s[0], axis=0)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref))


def test_pod_weighted_average(topo):
    v = jnp.stack([jnp.full((4,), 1.0), jnp.full((4,), 3.0)])
    w = jnp.asarray([0.25, 0.75])
    out = votes.pod_weighted_average(topo, v, w)
    np.testing.assert_allclose(np.asarray(out), 2.5)
    assert out.shape == v.shape  # broadcast back to every pod


def test_weighted_mean_dev(topo):
    g = jnp.arange(12, dtype=jnp.float32).reshape(1, 3, 4)
    w = jnp.asarray([[0.5, 0.25, 0.25]])
    out = votes.weighted_mean_dev(topo, g, w)
    ref = 0.5 * g[0, 0] + 0.25 * g[0, 1] + 0.25 * g[0, 2]
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref))
