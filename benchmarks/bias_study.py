"""Head-to-head drift-correction bias study (``BENCH_bias.json``).

Runs the ``ref_fed`` oracle on the synthetic EMNIST-like task under the
paper's SEVERE inter-cluster regime (Dirichlet(alpha=0.1) class skew
across edges) and compares the whole method axis sharing the pre-sign
correction slot:

    hier_sgd              full-precision baseline (no bias to correct)
    hier_signsgd          plain sign-voting (the biased trajectory)
    dc_hier_signsgd       cloud-assisted anchor delta (the paper)
    scaffold_hier_signsgd per-client SCAFFOLD control variates
    mtgc_hier_signsgd     MTGC two-timescale edge/cloud correction

under the PR-5 participation regimes (full quorum / Bernoulli(0.5)
sampling / unequal |D_qk| shares, pinned per-round masks from
``core.clients``).  Each cell records the test-loss trajectory, final
loss/accuracy and the per-round DRIFT NORM

    drift(t) = sqrt( sum_q ew_q || c^(t) - c_q^(t) ||^2 )

measured from the share-weighted anchor gradients at w^(t) -- the
heterogeneity-induced bias the corrections exist to cancel.  The drift
trajectory is method-comparable (same w-independent definition), so the
JSON makes "which correction keeps the model nearest the unbiased
descent direction" directly visible.

A second axis tells the EDGE-ASSIGNMENT story under severe intra+inter
skew (Dirichlet alpha=0.1 across edges AND alpha_client=0.1 within
them): {random, clustered} client->edge assignment x {plain, DC,
SCAFFOLD, MTGC}.  Random scatter mixes the skewed clients so every edge
looks alike (small inter-edge drift, large intra-edge variance);
clustered assignment (``data.cluster``, label-histogram signatures)
concentrates similar clients per edge, maximizing exactly the
inter-cluster bias the corrections cancel -- the 2x2 shows how much of
the correction's win the placement policy can claim.

  PYTHONPATH=src python benchmarks/bias_study.py [--fast] [--out PATH]

The default profile regenerates the checked-in BENCH_bias.json.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.core import clients as vclients
from repro.core import ref_fed
from repro.data import emnist_like
from repro.models import mlp

METHODS = ("hier_sgd", "hier_signsgd", "dc_hier_signsgd",
           "scaffold_hier_signsgd", "mtgc_hier_signsgd")
REGIMES = ("full", "sampled", "weighted")
# the assignment story compares the sign-voting family only (hier_sgd
# has no sign bias for the placement policy to move)
ASSIGN_METHODS = ("hier_signsgd", "dc_hier_signsgd",
                  "scaffold_hier_signsgd", "mtgc_hier_signsgd")
ASSIGNS = ("random", "clustered")
ALPHA_CLIENT = 0.1
SCHEMA = "bias_study_v2"

# K virtual clients per physical device slice: the oracle hosts them as
# K more entries per edge (devices_per_edge * K clients under edge q)
K_CLIENTS = 2
SEED = 0


def _profile(fast: bool) -> dict:
    if fast:
        return dict(q_edges=2, devices_per_edge=2, rounds=2, t_e=5,
                    batch=32, n_train=800, n_test=400)
    return dict(q_edges=4, devices_per_edge=5, rounds=6, t_e=10,
                batch=32, n_train=4000, n_test=1000)


def _vote_weights(regime: str, q_edges: int, n: int):
    """Integer |D_qk| vote weights per (edge, client) -- unit for the
    unweighted regimes, deterministic unequal 1..5 for 'weighted'."""
    if regime != "weighted":
        return [[1] * n for _ in range(q_edges)]
    return [[(q + 3 * k) % 5 + 1 for k in range(n)]
            for q in range(q_edges)]


def _mask(regime: str, cc, q_edges: int, devs: int, t: int, n: int):
    if regime != "sampled":
        return [[True] * n for _ in range(q_edges)]
    m = np.asarray(vclients.participation_mask(cc, q_edges, devs, t)) > 0.5
    return [list(m.reshape(q_edges, n)[q]) for q in range(q_edges)]


def _drift_norm(state, shares, ew, anchors) -> float:
    """sqrt(sum_q ew_q ||c - c_q||^2) from the share-weighted anchor
    gradients at the current w (the paper's inter-cluster bias)."""
    c_qs = []
    for q in range(len(anchors)):
        g = [mlp.grad_fn(state.w, anchors[q][k], None)
             for k in range(len(anchors[q]))]
        c_qs.append(ref_fed._tree_weighted_sum(shares[q], g))
    c = ref_fed._tree_weighted_sum(ew, c_qs)
    tot = 0.0
    for q, c_q in enumerate(c_qs):
        sq = sum(float(np.sum((np.asarray(u) - np.asarray(v)) ** 2))
                 for u, v in zip(jax.tree.leaves(c), jax.tree.leaves(c_q)))
        tot += ew[q] * sq
    return float(np.sqrt(tot))


def run_cell(method: str, regime: str, prof: dict,
             assign: str = "fixed",
             alpha_client: float | None = None) -> dict:
    q_edges, devs = prof["q_edges"], prof["devices_per_edge"]
    n = devs * K_CLIENTS                     # clients per edge
    dcfg = emnist_like.FedDataCfg(
        n_train=prof["n_train"], n_test=prof["n_test"], alpha=0.1,
        iid=False, seed=SEED, q_edges=q_edges, devices_per_edge=n,
        alpha_client=alpha_client, edge_assign=assign)
    dev, test, ew, dw = emnist_like.make_federated_data(dcfg)
    rng = np.random.default_rng(SEED)
    cc = vclients.ClientConfig(count=K_CLIENTS, participation="bernoulli",
                               rate=0.5, seed=11)
    vw = _vote_weights(regime, q_edges, n)
    # raw (unnormalized) aggregation shares follow the vote weights in
    # the weighted regime; reweighting renormalizes to the participants
    raw = [[dw[q][k] * vw[q][k] for k in range(n)] for q in range(q_edges)]
    cfg = ref_fed.HierConfig(mu=5e-3, mu_sgd=0.5, t_e=prof["t_e"],
                             rho=0.2, method=method)
    state = ref_fed.init_state(mlp.init_mlp(jax.random.PRNGKey(SEED)),
                               q_edges)
    losses, accs, drifts = [], [], []
    t0 = time.time()
    for t in range(prof["rounds"]):
        batches = [[[emnist_like.device_batches(dev, q, k, prof["batch"],
                                                rng)
                     for _ in range(prof["t_e"])] for k in range(n)]
                   for q in range(q_edges)]
        anchors = [[emnist_like.device_batches(dev, q, k,
                                               2 * prof["batch"], rng)
                    for k in range(n)] for q in range(q_edges)]
        mask = _mask(regime, cc, q_edges, devs, t, n)
        shares = [ref_fed._participating_shares(raw[q], mask[q])
                  for q in range(q_edges)]
        drifts.append(round(_drift_norm(state, shares, ew, anchors), 5))
        state = ref_fed.global_round(
            state, cfg, mlp.grad_fn, batches, anchors, ew, raw,
            jax.random.PRNGKey(1000 + t), device_mask=mask,
            vote_weights=vw, reweight_participation=True)
        losses.append(round(float(mlp.loss_fn(
            state.w, {"x": test["x"][:512], "y": test["y"][:512]})), 5))
        accs.append(round(float(mlp.accuracy(state.w, test)), 4))
    return {
        "method": method, "regime": regime,
        "assign": assign, "alpha_client": alpha_client,
        "loss": losses, "final_loss": losses[-1],
        "acc": accs, "final_acc": accs[-1],
        "drift_norm": drifts,
        "wall_s": round(time.time() - t0, 2),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI profile: 2x2 fleet, 2 rounds")
    ap.add_argument("--out", default=str(
        pathlib.Path(__file__).resolve().parents[1] / "BENCH_bias.json"))
    args = ap.parse_args()

    prof = _profile(args.fast)
    cells = []
    print("method,regime,assign,final_loss,final_acc,drift_norm_last")
    for regime in REGIMES:
        for method in METHODS:
            cell = run_cell(method, regime, prof)
            cells.append(cell)
            print(f"{method},{regime},fixed,{cell['final_loss']},"
                  f"{cell['final_acc']},{cell['drift_norm'][-1]}")

    # the 2x2 assignment story: severe intra+inter skew, full quorum
    for assign in ASSIGNS:
        for method in ASSIGN_METHODS:
            cell = run_cell(method, "full", prof, assign=assign,
                            alpha_client=ALPHA_CLIENT)
            cells.append(cell)
            print(f"{method},full,{assign},{cell['final_loss']},"
                  f"{cell['final_acc']},{cell['drift_norm'][-1]}")

    by = {(c["method"], c["regime"]): c for c in cells
          if c["assign"] == "fixed"}
    by_assign = {(c["method"], c["assign"]): c for c in cells
                 if c["assign"] != "fixed"}
    checks = {
        # every correction should end at or below plain sign-voting's
        # loss under the severe non-IID full-quorum regime (recorded,
        # not asserted: the dashboard diff is the regression signal)
        "corrections_beat_plain_full": {
            m: by[(m, "full")]["final_loss"]
            <= by[("hier_signsgd", "full")]["final_loss"]
            for m in ("dc_hier_signsgd", "scaffold_hier_signsgd",
                      "mtgc_hier_signsgd")},
        "final_loss_full": {m: by[(m, "full")]["final_loss"]
                            for m in METHODS},
        "final_loss_sampled": {m: by[(m, "sampled")]["final_loss"]
                               for m in METHODS},
        # placement story: first drift reading per assignment mode --
        # random scatter should START with less inter-edge drift than
        # clustered placement of the same skewed clients
        "drift0_by_assign": {
            a: {m: by_assign[(m, a)]["drift_norm"][0]
                for m in ASSIGN_METHODS} for a in ASSIGNS},
        "final_loss_by_assign": {
            a: {m: by_assign[(m, a)]["final_loss"]
                for m in ASSIGN_METHODS} for a in ASSIGNS},
    }
    report = {
        "schema": SCHEMA,
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "profile": ("fast" if args.fast else "default"),
            **prof,
            "clients_per_device": K_CLIENTS,
            "alpha": 0.1, "alpha_client": ALPHA_CLIENT,
            "rho": 0.2, "mu": 5e-3, "mu_sgd": 0.5,
            "seed": SEED,
            "note": "ref_fed oracle on the synthetic EMNIST-like task, "
                    "Dirichlet(0.1) inter-edge skew; drift_norm is "
                    "sqrt(sum_q ew_q ||c - c_q||^2) from share-weighted "
                    "anchor grads at w^(t) before each round.  assign "
                    "cells add Dirichlet(alpha_client) intra-edge skew "
                    "and regroup clients by data.cluster signatures.",
        },
        "methods": list(METHODS),
        "regimes": list(REGIMES),
        "assignments": list(ASSIGNS),
        "cells": cells,
        "checks": checks,
    }
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
