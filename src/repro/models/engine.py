"""Model engine: schedule-driven forwards for train (both regimes) & serve.

The engine owns the scan/vmap structure so that the SAME block code serves:

  * ``loss_single``  -- one replica's loss (replicated regime; ``hier``
    vmaps it over [P, D] and differentiates w.r.t. the device copies);
  * ``loss_master``  -- FSDP regime; the engine scans layers at top level
    and lifts each layer's master shard via the in-backward-vote
    ``fsdp_lift`` (passed in by ``hier``), vmapping the block over [P, D];
  * ``prefill`` / ``decode_step`` -- single-model serving with KV caches
    (per-layer gather for FSDP-stored params; no autodiff).

Layer schedules are lists of Segments; a Segment scans ``repeats`` times
over its ``layout`` (e.g. gemma3: 5 local + 1 global per repeat).  Tied
blocks (zamba2's shared attention) keep ONE param set applied at every
occurrence -- their lifts happen outside the scan so tied gradients sum
BEFORE the sign, as the paper's per-coordinate semantics require.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.blocks import BlockDef, Ctx
from repro.models.config import LMConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Segment:
    layout: tuple[tuple[str, int], ...]      # (block_name, count per repeat)
    repeats: int
    tied: frozenset = frozenset()            # block names with shared params


@dataclasses.dataclass
class ArchDef:
    cfg: LMConfig
    blocks: dict[str, BlockDef]
    segments: list[Segment]
    enc_blocks: dict[str, BlockDef] | None = None
    enc_segments: list[Segment] | None = None
    mtp_block: BlockDef | None = None


def stack_counts(segments: list[Segment]) -> dict[str, int]:
    counts: dict[str, int] = {}
    for seg in segments:
        for bname, cnt in seg.layout:
            if bname in seg.tied:
                counts.setdefault(bname, 0)
            else:
                counts[bname] = counts.get(bname, 0) + cnt * seg.repeats
    return counts


def _stack_init(bd: BlockDef, rng, n: int):
    if n == 0:                                # tied: single param set
        return bd.init(rng)
    return jax.vmap(bd.init)(jax.random.split(rng, n))


def _prepend(spec_tree, *axes):
    return jax.tree.map(lambda s: P(*axes, *s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Plans: how a block application consumes params (plain vs lifted)
# ---------------------------------------------------------------------------

class ReplicatedPlan:
    """Single-replica application; params are plain arrays."""

    def __init__(self, cfg: LMConfig, remat: bool):
        self.remat = remat and cfg.remat
        self.aux0 = jnp.zeros((), jnp.float32)

    def act(self, x):
        return x

    def block(self, bd: BlockDef, lp, ld, x, ctx, cache):
        fn = bd.apply
        if self.remat and ctx.mode == "train":
            fn = jax.checkpoint(
                lambda p_, x_: bd.apply(p_, x_, ctx, cache))
            y, aux, nc = fn(lp, x)
            return y, aux, nc
        return fn(lp, x, ctx, cache)

    def lift_once(self, subtree, dsub, mspecs, cspecs):
        return subtree                        # params already usable


class FsdpPlan:
    """[P, D]-batched application; params lifted per layer via fsdp_lift."""

    def __init__(self, cfg: LMConfig, lift, master_specs, compute_specs,
                 pd: tuple[int, int], remat: bool, topo=None,
                 act_spec=None):
        self.cfg = cfg
        self.lift = lift
        self.master_specs = master_specs      # per-leaf, WITHOUT pod dim
        self.compute_specs = compute_specs
        self.aux0 = jnp.zeros(pd, jnp.float32)
        self.remat = remat and cfg.remat
        self.topo = topo
        self.act_spec = act_spec              # inter-layer residual layout

    def act(self, x):
        """Megatron-SP-style residual sharding: store the inter-layer
        activation with its sequence dim sharded over 'model' (the layer
        boundary all-gather/reduce-scatter pair is inserted by GSPMD).
        Cuts remat-residual memory by the TP degree (DESIGN.md Sec. 5)."""
        if self.topo is None or self.act_spec is None:
            return x
        seq_dim = len(self.act_spec) - 2
        if x.shape[seq_dim] % max(self.topo.model_shards, 1):
            return x
        return self.topo.constrain(x, self.act_spec)

    def block(self, bd: BlockDef, lp_and_specs, ld, x, ctx, cache):
        lp, mspec, cspec = lp_and_specs
        assert cache is None, "fsdp regime is train-only"

        def run(lp_, ld_, x_):
            lp_dev = self.lift(lp_, ld_, mspec, cspec)
            def one(w, xx):
                y, aux, _ = bd.apply(w, xx, ctx, None)
                return y, aux
            y, aux = jax.vmap(jax.vmap(one))(lp_dev, x_)
            return y, aux

        if self.remat and ctx.mode == "train":
            run = jax.checkpoint(run)
        y, aux = run(lp, ld, x)
        return self.act(y), aux, None

    def lift_once(self, subtree, dsub, mspecs, cspecs):
        return self.lift(subtree, dsub, mspecs, cspecs)


# ---------------------------------------------------------------------------
# Segment runner
# ---------------------------------------------------------------------------

def run_segments(plan, arch: ArchDef, segments, stacks, dstacks, x, ctx,
                 caches=None):
    """Apply all segments.  Returns (x, aux, new_caches)."""
    fsdp = isinstance(plan, FsdpPlan)
    cursors = {b: 0 for b in arch_all_blocks(arch, segments)}
    new_caches = {} if caches is not None else None
    blocks = {**arch.blocks, **(arch.enc_blocks or {})}

    # pre-lift tied params once (grads over occurrences sum pre-sign)
    tied_params = {}
    for seg in segments:
        for bname in seg.tied:
            if bname not in tied_params:
                bd = blocks[bname]
                if fsdp:
                    tied_params[bname] = (
                        plan.lift_once(stacks[bname], dstacks[bname],
                                       plan.master_specs[bname],
                                       bd.specs),
                        None, None)
                else:
                    tied_params[bname] = stacks[bname]

    def slice_stack(a, c0, n_seg, repeats, cnt):
        """Slice a stacked leaf for one segment's scan.

        Replicated: [n, ...] -> [repeats, cnt, ...].
        FSDP: masters carry a leading pod dim [P, n, ...] -> move the
        layer axis out front: [repeats, cnt, P, ...].
        """
        if fsdp:
            sl = jnp.moveaxis(a[:, c0:c0 + n_seg], 1, 0)
            return sl.reshape((repeats, cnt) + sl.shape[1:])
        sl = a[c0:c0 + n_seg]
        return sl.reshape((repeats, cnt) + sl.shape[1:])

    aux = plan.aux0
    for seg in segments:
        # slice this segment's params/caches per block
        seg_p, seg_d, seg_c = {}, {}, {}
        for bname, cnt in seg.layout:
            n_seg = cnt * seg.repeats
            if bname not in seg.tied:
                c0 = cursors[bname]
                seg_p[bname] = jax.tree.map(
                    lambda a: slice_stack(a, c0, n_seg, seg.repeats, cnt),
                    stacks[bname])
                if dstacks is not None:
                    seg_d[bname] = jax.tree.map(
                        lambda a: slice_stack(a, c0, n_seg, seg.repeats,
                                              cnt), dstacks[bname])
                cursors[bname] = c0 + n_seg
            if caches is not None:
                ck = f"{bname}"
                c0c = cursors.setdefault(ck + "#cache", 0)
                seg_c[bname] = jax.tree.map(
                    lambda a: a[c0c:c0c + n_seg].reshape(
                        (seg.repeats, cnt) + a.shape[1:]), caches[bname])
                cursors[ck + "#cache"] = c0c + n_seg

        def body(carry, xs):
            x_, aux_ = carry
            ps, ds, cs = xs
            emitted = {}
            for bname, cnt in seg.layout:
                bd = blocks[bname]
                tied = bname in seg.tied

                def apply_one(lp, ld, x__, cache_slice):
                    if fsdp:
                        lp_in = (tied_params[bname] if tied
                                 else (lp, plan.master_specs[bname],
                                       bd.specs))
                        if tied:
                            # already lifted: direct vmap apply
                            lifted, _, _ = tied_params[bname]
                            def one(w, xx):
                                y, a_, _ = bd.apply(w, xx, ctx, None)
                                return y, a_
                            y, a_ = jax.vmap(jax.vmap(one))(lifted, x__)
                            return y, a_, None
                        return plan.block(bd, lp_in, ld, x__, ctx,
                                          cache_slice)
                    lp_use = tied_params[bname] if tied else lp
                    return plan.block(bd, lp_use, None, x__, ctx,
                                      cache_slice)

                if cnt == 1:
                    lp = None if tied else jax.tree.map(
                        lambda a: a[0], ps.get(bname))
                    ld = None if (tied or ds is None) else jax.tree.map(
                        lambda a: a[0], ds.get(bname))
                    csl = (jax.tree.map(lambda a: a[0], cs[bname])
                           if cs is not None and bname in cs else None)
                    x_, a_, nc = apply_one(lp, ld, x_, csl)
                    aux_ = aux_ + a_
                    if nc is not None:
                        emitted[bname] = jax.tree.map(
                            lambda v: v[None], nc)
                else:
                    def inner(c2, xs2):
                        x2, a2 = c2
                        lp2, ld2, cache2 = xs2
                        y, a_, nc2 = apply_one(lp2, ld2, x2, cache2)
                        return (y, a2 + a_), nc2

                    xs2 = (None if tied else ps[bname],
                           None if (tied or ds is None) else ds[bname],
                           cs[bname] if (cs is not None and bname in cs)
                           else None)
                    (x_, aux_), ncs = jax.lax.scan(inner, (x_, aux_), xs2,
                                                   length=cnt)
                    if ncs is not None:
                        emitted[bname] = ncs
            return (x_, aux_), (emitted or None)

        xs = (seg_p or None, seg_d or None, seg_c or None)
        (x, aux), emitted = jax.lax.scan(body, (x, aux), xs,
                                         length=seg.repeats)
        if caches is not None and emitted:
            for bname, cnt in seg.layout:
                if bname in emitted:
                    flat = jax.tree.map(
                        lambda a: a.reshape((-1,) + a.shape[2:]),
                        emitted[bname])
                    new_caches.setdefault(bname, []).append(flat)

    if new_caches is not None:
        new_caches = {b: (jax.tree.map(
            lambda *xs: jnp.concatenate(xs, 0), *v) if len(v) > 1 else v[0])
            for b, v in new_caches.items()}
    return x, aux, new_caches


def arch_all_blocks(arch: ArchDef, segments) -> list[str]:
    names = []
    for seg in segments:
        for bname, _ in seg.layout:
            if bname not in names:
                names.append(bname)
    return names
