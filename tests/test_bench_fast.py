"""Smoke test for the ``benchmarks/run.py --fast`` CI profile: it must
complete in seconds (cost model, no CPU training) and emit the same
row names / JSON schema as the real-training profile."""
import json
import pathlib
import subprocess
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]

EXPECT_FIG2 = {f"fig2/{tag}/{m}"
               for tag in ("iid", "noniid")
               for m in ("hier_sgd", "hier_local_qsgd", "hier_signsgd",
                         "dc_hier_signsgd")}
EXPECT_FIG3 = {f"fig3/{tag}/te{te}/{m}"
               for tag in ("iid", "noniid") for te in (5, 15)
               for m in ("hier_signsgd", "dc_hier_signsgd")}
EXPECT_FIG4 = {f"fig4/rho{r}" for r in (0.0, 0.2, 1.0)}
# virtual-client scale-out: K=64 clients/device, Bernoulli(0.1)
# participation -- the nightly row tracking the participating-uplink
# accounting (uplink scales with sampled K, not the fleet size)
EXPECT_CLIENTS = {f"clients/K64_p0.1/{m}"
                  for m in ("hier_signsgd", "dc_hier_signsgd")}
# drift-correction method axis: loss proxy + per-client downlink bytes
# (dc anchor vs scaffold c_global vs mtgc two-term accounting)
EXPECT_METHODS = {f"methods/{m}"
                  for m in ("hier_signsgd", "dc_hier_signsgd",
                            "scaffold_hier_signsgd", "mtgc_hier_signsgd")}
# cloud sync schedule: per-round wall-clock with the cloud RTT on the
# critical path (sync) vs hidden behind a round of local work (overlap)
EXPECT_OVERLAP = {f"overlap/rtt{r}ms/{sched}/{m}"
                  for r in (1000, 10000) for sched in ("sync", "overlap")
                  for m in ("hier_signsgd", "dc_hier_signsgd")}


def test_fast_profile_is_fast_and_schema_stable(tmp_path):
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
           "HOME": str(tmp_path)}
    t0 = time.time()
    r = subprocess.run(
        [sys.executable, str(ROOT / "benchmarks" / "run.py"), "--fast",
         "--out-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=120, env=env)
    wall = time.time() - t0
    assert r.returncode == 0, r.stderr[-2000:]
    # "completes in seconds": generous bound still far below one real
    # CPU training round of fig2 (interpreter startup dominates)
    assert wall < 90, wall

    report = json.loads((tmp_path / "bench_results.json").read_text())
    assert set(report) == {"rows"}
    rows = report["rows"]
    assert rows and all(set(row) == {"name", "us_per_call", "derived"}
                        for row in rows)
    names = {row["name"] for row in rows}
    for expect in (EXPECT_FIG2, EXPECT_FIG3, EXPECT_FIG4, EXPECT_CLIENTS,
                   EXPECT_METHODS, EXPECT_OVERLAP):
        assert expect <= names, expect - names
    by_name = {row["name"]: row for row in rows}
    for name in EXPECT_FIG2 | EXPECT_FIG3 | EXPECT_FIG4:
        row = by_name[name]
        assert row["us_per_call"] > 0
        key = "final_acc=" if name.startswith("fig2") else "final_loss="
        assert key in row["derived"], row
        assert "src=cost_model" in row["derived"], row
    for name in EXPECT_CLIENTS:
        row = by_name[name]
        assert row["us_per_call"] > 0
        assert "uplink_mbits_round=" in row["derived"], row
        assert "participants=" in row["derived"], row
        assert "src=cost_model" in row["derived"], row
    for name in EXPECT_METHODS:
        row = by_name[name]
        assert row["us_per_call"] > 0
        assert "final_loss=" in row["derived"], row
        assert "downlink_kb_round=" in row["derived"], row
        assert "src=cost_model" in row["derived"], row
    # the corrections pay strictly more downlink than plain sign-voting,
    # and mtgc's cloud-amortized second term tops the table
    def _down(name):
        d = by_name[name]["derived"]
        return float(d.split("downlink_kb_round=")[1].split()[0])
    assert (_down("methods/hier_signsgd")
            < _down("methods/dc_hier_signsgd")
            == _down("methods/scaffold_hier_signsgd")
            < _down("methods/mtgc_hier_signsgd"))
    for name in EXPECT_OVERLAP:
        row = by_name[name]
        assert row["us_per_call"] > 0
        assert "cloud_rtt_ms=" in row["derived"], row
        assert "hidden_frac=" in row["derived"], row
        assert "speedup_vs_sync=" in row["derived"], row
        assert "src=cost_model" in row["derived"], row
    # overlap never pays MORE than sync, and the saving is real for
    # every (rtt, method) pair: max(round, RTT) < round + RTT whenever
    # both are positive
    for name in EXPECT_OVERLAP:
        if "/overlap/" not in name:
            continue
        sync_row = by_name[name.replace("/overlap/", "/sync/")]
        assert by_name[name]["us_per_call"] < sync_row["us_per_call"], (
            name)
        speed = float(by_name[name]["derived"]
                      .split("speedup_vs_sync=")[1].split()[0])
        assert speed > 1.0, name
    # table2 rows ride along unchanged
    assert any(n.startswith("table2/") for n in names)
