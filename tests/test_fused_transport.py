"""Fused flat-buffer transport: bit-identity with the per-leaf transports
at the votes level and inside full ``make_hier_step`` train steps.

The multi-device (8 host CPUs) trajectory parity runs in a subprocess --
see helpers/fused_parity_check.py.
"""
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import hier, signs, votes
from repro.core.topology import single_device_topology

HELPERS = pathlib.Path(__file__).parent / "helpers"
SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


@pytest.fixture(scope="module")
def topo():
    return single_device_topology()


def _tree(seed=0, pd=(2, 5), dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(key, pd + (3, 33), dtype),
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   pd + (64,), dtype),
            "v": jax.random.normal(jax.random.fold_in(key, 2),
                                   pd + (7, 32), dtype)}


SPECS = {"w": P(None, None), "b": P(None), "v": P(None, None)}


@pytest.mark.parametrize("use_mask", [False, True])
def test_fused_vote_identical_to_per_leaf(topo, use_mask):
    tree = _tree()
    mask = None
    if use_mask:
        mask = jnp.asarray([[1, 1, 0, 1, 0], [1, 0, 0, 1, 1]],
                           jnp.float32) > 0.5
    vf = votes.fused_sign_vote(topo, tree, None, 0.0, mask)
    for k, leaf in tree.items():
        s = signs.sgn(leaf)
        v_ag = votes.majority_vote_dev(topo, s, mask, "ag_packed", SPECS[k])
        v_ar = votes.vote_ar_int8(topo, s, mask)
        assert vf[k].shape == leaf.shape[:1] + leaf.shape[2:]
        np.testing.assert_array_equal(np.asarray(vf[k]), np.asarray(v_ag))
        np.testing.assert_array_equal(np.asarray(vf[k]), np.asarray(v_ar))


def test_fused_vote_dc_folding(topo):
    """sgn(u + rho*delta) fused pre-sign == per-leaf corrected vote."""
    tree = _tree(seed=3)
    delta = {k: jax.random.normal(jax.random.PRNGKey(9),
                                  (2,) + v.shape[2:], v.dtype)
             for k, v in tree.items()}
    mask = jnp.asarray([[1, 1, 1, 0, 1], [1, 1, 1, 1, 1]]) > 0
    vf = votes.fused_sign_vote(topo, tree, delta, 0.3, mask)
    for k, leaf in tree.items():
        u = leaf + 0.3 * delta[k][:, None].astype(leaf.dtype)
        v_ag = votes.majority_vote_dev(topo, signs.sgn(u), mask,
                                       "ag_packed", SPECS[k])
        np.testing.assert_array_equal(np.asarray(vf[k]), np.asarray(v_ag))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_pallas_interpret_route_matches_jnp(topo, monkeypatch, dtype):
    """REPRO_FUSED_PALLAS=interpret drives the real kernels (interpret
    mode on CPU) through the same chain -- must match the jnp path
    bitwise, including bf16 trees (DC pre-added in leaf dtype: the
    kernel's f32 fold is only used for all-f32 trees)."""
    tree = _tree(seed=4, pd=(1, 4), dtype=dtype)
    delta = {k: jax.random.normal(jax.random.PRNGKey(8),
                                  (1,) + v.shape[2:], v.dtype)
             for k, v in tree.items()}
    mask = jnp.asarray([[1.0, 0.0, 1.0, 1.0]]) > 0.5
    v_jnp = votes.fused_sign_vote(topo, tree, delta, 0.5, mask)
    monkeypatch.setenv("REPRO_FUSED_PALLAS", "interpret")
    v_krn = votes.fused_sign_vote(topo, tree, delta, 0.5, mask)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(v_jnp[k]),
                                      np.asarray(v_krn[k]))


def test_fused_pallas_delta_slab_mapping(topo, monkeypatch):
    """Multi-tile buffer (rows not a power of two) with DC folded in the
    kernel: the per-voter delta re-read via the BlockSpec index map must
    match the jnp path for every (pod, device) slab."""
    key = jax.random.PRNGKey(11)
    # ~6 tiles of 4096 coords -> rows=6, row block 2, 3 blocks per slab
    tree = {"m": jax.random.normal(key, (2, 3, 24000)),
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   (2, 3, 500))}
    delta = {k: jax.random.normal(jax.random.fold_in(key, 2),
                                  (2,) + v.shape[2:], v.dtype)
             for k, v in tree.items()}
    v_jnp = votes.fused_sign_vote(topo, tree, delta, 0.4, None)
    monkeypatch.setenv("REPRO_FUSED_PALLAS", "interpret")
    v_krn = votes.fused_sign_vote(topo, tree, delta, 0.4, None)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(v_jnp[k]),
                                      np.asarray(v_krn[k]))


def test_per_leaf_fused_dispatch_falls_back(topo):
    """Per-leaf callers (FSDP lift) route 'fused' through ag_packed /
    ar_int8 -- identical votes either way."""
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 33))
    s = signs.sgn(x)
    out = votes.majority_vote_dev(topo, s, None, "fused", P(None))
    ref = signs.majority_vote(s[0], axis=0)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref))


def test_algo_config_validates_transport():
    with pytest.raises(ValueError):
        hier.AlgoConfig(transport="bogus")
    with pytest.raises(ValueError):
        hier.AlgoConfig(method="bogus")
    hier.AlgoConfig(transport="fused")          # accepted


def _run_steps(topo, transport, method, steps=6, **algo_kw):
    def loss_fn(params, batch, rng):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    w0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 33)) * 0.3,
          "b": jnp.zeros((33,))}
    specs = {"w": P(None, None), "b": P(None)}
    xs = jax.random.normal(jax.random.PRNGKey(7), (6, 1, 1, 8, 16))
    ys = jnp.einsum("spdbi,io->spdbo", xs,
                    jax.random.normal(jax.random.PRNGKey(9), (16, 33)))
    algo = hier.AlgoConfig(method=method, mu=5e-3, t_e=3, rho=1.0,
                           transport=transport,
                           compute_dtype=jnp.float32,
                           master_dtype=jnp.float32,
                           delta_dtype=jnp.float32, **algo_kw)
    bundle = hier.ModelBundle(loss=loss_fn, compute_specs=specs,
                              master_specs=specs)
    init_fn, step = hier.make_hier_step(topo, algo, bundle)
    state = init_fn(w0, jax.random.PRNGKey(1))
    jstep = jax.jit(step)
    ew, dw, mask = jnp.ones((1,)), jnp.ones((1, 1)), jnp.ones((1, 1))
    for t in range(steps):
        state, _ = jstep(state, {"train": {"x": xs[t], "y": ys[t]}},
                         ew, dw, mask)
    return jax.tree.map(np.asarray, state.params)


@pytest.mark.parametrize("method", ["hier_signsgd", "dc_hier_signsgd"])
@pytest.mark.parametrize("extra", [{}, {"error_feedback": True},
                                   {"momentum": 0.9}])
def test_train_step_parity_single_device(topo, method, extra):
    ref = _run_steps(topo, "ag_packed", method, **extra)
    got = _run_steps(topo, "fused", method, **extra)
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k])


@pytest.mark.slow
def test_train_step_parity_multidevice():
    """8-CPU mesh: ag_packed / ar_int8 / fused produce bitwise-identical
    trajectories (DC + plain, straggler masks, EF)."""
    env = {"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin", "HOME": "/tmp"}
    r = subprocess.run(
        [sys.executable, str(HELPERS / "fused_parity_check.py")],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, (
        f"fused_parity_check failed:\nSTDOUT:\n{r.stdout[-4000:]}\n"
        f"STDERR:\n{r.stderr[-4000:]}")
    assert "fused transport parity OK" in r.stdout
