"""Sign-compression primitives (pure jnp reference ops).

These are the coordinate-wise building blocks of HierSignSGD /
DC-HierSignSGD (Kazemi et al., 2026):

  * ``sgn``           -- the paper's element-wise sign operator (maps to {-1,+1}).
  * ``pack_signs``    -- 1 bit/coordinate wire format (uint32 words), the
                         faithful device->edge uplink payload.
  * ``unpack_signs``  -- inverse of ``pack_signs``.
  * ``majority_vote`` -- s_q = sgn(sum_k sgn(g_k)), with optional voter
                         masking (straggler/fault quorum).
  * ``ternary_quantize`` -- the unbiased stochastic ternary quantizer used
                         by the Hier-Local-QSGD baseline (paper Sec. V-B).

Conventions
-----------
``sgn(0) = +1`` so that every coordinate is representable in one bit.  Vote
ties (possible with an even voter count, with masked voters, or with
weighted tallies that cancel exactly) therefore resolve to +1
deterministically; the packed and integer transports are bit-identical by
construction (tested in tests/test_signs.py).

Weighted votes: the voter ``mask`` generalizes to nonnegative *integer*
vote weights (the data shares ``|D_qk|`` of ``core.clients``) -- the vote
becomes the weighted popcount ``sgn(sum_k w_k sgn(g_k))`` with the same
tie rule.  A weight of 0 abstains; an edge whose whole quorum abstains
(all weights 0) returns vote 0, so the descent step leaves ``v_q``
unchanged for that round.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

PACK_WIDTH = 32  # sign bits per uint32 word


def sgn(x: jax.Array) -> jax.Array:
    """Element-wise sign into {-1, +1} (int8); sgn(0) = +1."""
    return jnp.where(x >= 0, jnp.int8(1), jnp.int8(-1))


def _pad_to_multiple(flat: jax.Array, m: int) -> jax.Array:
    pad = (-flat.shape[-1]) % m
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.ones(flat.shape[:-1] + (pad,), flat.dtype)], axis=-1
        )
    return flat


def packed_size(n: int) -> int:
    """Number of uint32 words used to carry ``n`` sign bits."""
    return (n + PACK_WIDTH - 1) // PACK_WIDTH


def pack_signs(signs: jax.Array) -> jax.Array:
    """Pack {-1,+1} signs into uint32 words along the last axis.

    signs: (..., n) int8 in {-1, +1}  ->  (..., ceil(n/32)) uint32.
    Positive sign -> bit 1.  Padding bits are 1 (+1 sign).
    """
    flat = _pad_to_multiple(signs, PACK_WIDTH)
    bits = (flat > 0).astype(jnp.uint32)
    bits = bits.reshape(bits.shape[:-1] + (-1, PACK_WIDTH))
    shifts = jnp.arange(PACK_WIDTH, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_signs(words: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`pack_signs`; returns (..., n) int8 in {-1,+1}."""
    shifts = jnp.arange(PACK_WIDTH, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (-1,))[..., :n]
    return jnp.where(bits == 1, jnp.int8(1), jnp.int8(-1))


def majority_vote(signs: jax.Array, mask: jax.Array | None = None,
                  axis: int = 0) -> jax.Array:
    """Edge-server majority vote  s = sgn(sum_k w_k sgn_k)  over ``axis``.

    signs: int8 {-1,+1} with voter axis ``axis``.
    mask:  optional per-voter weights broadcastable to ``signs`` --
           {0,1} masks or nonnegative integer data shares ``|D_qk|``
           (the weighted popcount vote); weight 0 abstains (contributes
           0 to the tally).
    Ties resolve to +1 (consistent with ``sgn``); an empty quorum (all
    weights 0) abstains entirely: vote 0.
    """
    tally = signs.astype(jnp.int32)
    if mask is None:
        return sgn(jnp.sum(tally, axis=axis).astype(jnp.float32))
    m = jnp.asarray(mask)
    if m.ndim < tally.ndim:   # [K] voter weights -> broadcast over leaf
        m = m.reshape(m.shape + (1,) * (tally.ndim - m.ndim))
    m = m.astype(jnp.int32)
    vote = sgn(jnp.sum(tally * m, axis=axis).astype(jnp.float32))
    n_eff = jnp.sum(m, axis=axis)
    return jnp.where(n_eff > 0, vote, jnp.int8(0))


def majority_vote_packed(words: jax.Array, n: int,
                         mask: jax.Array | None = None) -> jax.Array:
    """Majority vote from bit-packed per-voter words.

    words: (K, ceil(n/32)) uint32 -- one packed sign row per voter;
    mask: optional (K,) {0,1} voter mask or integer vote weights.
    Returns (n,) int8 vote.  Equivalent to
    ``majority_vote(unpack_signs(words, n), mask, axis=0)`` but computed via
    bit-plane popcount (this is the faithful "edge receives K one-bit
    uplinks and votes" path); weighted tallies and the empty-quorum
    abstention follow the same conventions.
    """
    shifts = jnp.arange(PACK_WIDTH, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)      # (K, w, 32)
    bits = bits.reshape(words.shape[0], -1)[:, :n]           # (K, n)
    if mask is not None:
        m = mask.astype(jnp.int32).reshape(-1, 1)
        pos = jnp.sum(bits.astype(jnp.int32) * m, axis=0)
        k_eff = jnp.sum(m)
    else:
        pos = jnp.sum(bits, axis=0).astype(jnp.int32)
        k_eff = words.shape[0]
    # vote = sgn(2*pos - k_eff); ties (2*pos == k_eff) -> +1.
    vote = jnp.where(2 * pos >= k_eff, jnp.int8(1), jnp.int8(-1))
    if mask is not None:
        vote = jnp.where(k_eff > 0, vote, jnp.int8(0))
    return vote


def ternary_quantize(x: jax.Array, rng: jax.Array) -> jax.Array:
    """Unbiased stochastic ternary quantizer (paper eq. in Sec. V-B).

    Q(x)_i = ||x||_2 * sign(x_i) with prob |x_i|/||x||_2, else 0; Q(0)=0.
    E[Q(x)] = x.  Wire cost ~ sign bit + support bit per coordinate + one
    32-bit scale (Table II row 'Hier-Local-QSGD').
    """
    norm = jnp.linalg.norm(x)
    p = jnp.where(norm > 0, jnp.abs(x) / jnp.maximum(norm, 1e-30), 0.0)
    keep = jax.random.uniform(rng, x.shape) < p
    return jnp.where(keep, norm * jnp.sign(x), 0.0).astype(x.dtype)


# ---------------------------------------------------------------------------
# Wire-cost accounting (Table II of the paper), in bits per device per
# global round, for a d-dimensional model and T_E local steps.
# ---------------------------------------------------------------------------

def uplink_bits(method: str, d: int, t_e: int, clients: int = 1,
                participation_rate: float = 1.0) -> int | float:
    """Device->edge uplink bits per global round (Table II).

    With K virtual clients per physical slice (``core.clients``) each
    PARTICIPATING client sends its own full per-client stream (1 bit
    per coordinate per local step for the sign methods, plus the DC
    anchor) and a masked-out client sends nothing, so the expected
    per-slice uplink is ``clients * participation_rate * base``.  The
    legacy single-client call (``clients=1``, full participation)
    returns the exact integer Table II entry; the virtual-client form
    is an expectation and may be fractional.  Consistency with the
    dry-run pricing (``benchmarks/cost_model.clients_rows``) is pinned
    by tests/test_signs.py.
    """
    if method == "hier_sgd":
        base = 32 * t_e * d
    elif method == "hier_local_qsgd":        # sign+support bits + scale
        base = t_e * (2 * d + 32)
    elif method == "hier_signsgd":
        base = t_e * d
    elif method == "dc_hier_signsgd":        # + one full-precision anchor
        base = t_e * d + 32 * d
    elif method in ("scaffold_hier_signsgd", "mtgc_hier_signsgd"):
        # the control-variate refresh uploads one full-precision anchor
        # gradient per participating client per round, exactly like DC
        base = t_e * d + 32 * d
    else:
        raise ValueError(f"unknown method {method!r}")
    if clients == 1 and participation_rate >= 1.0:
        return base
    return clients * participation_rate * base
