"""Async checkpointing: device->host transfer on the caller, serialization
on a background thread, so training never blocks on disk I/O.

Usage:
    saver = AsyncSaver(ckpt_dir, keep=3)
    saver.submit(step, state)     # returns immediately
    saver.wait()                  # drain (end of run / before restore)
"""
from __future__ import annotations

import queue
import threading

import jax

from repro.checkpoint import store


class AsyncSaver:
    """A failed background save is NEVER silently dropped: the writer
    thread records any raised exception (``BaseException`` -- a dying
    thread must not look like a successful save) and the next
    ``submit()`` / ``wait()`` re-raises it on the caller.  The thread
    itself survives the failure and keeps serving later saves; the
    sentinel ``task_done()`` runs unconditionally so ``wait()`` can
    never deadlock on a crashed item."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._err: BaseException | None = None
        self._t = threading.Thread(target=self._loop, daemon=True)
        self._t.start()

    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            try:
                step, host_tree = item
                store.save(self.ckpt_dir, step, host_tree, keep=self.keep)
            except BaseException as e:  # surfaced on next submit/wait
                self._err = e
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError(
                f"background checkpoint save failed (step dropped from "
                f"{self.ckpt_dir})") from err

    def submit(self, step: int, tree):
        self._raise_pending()
        if not self._t.is_alive():
            raise RuntimeError(
                "AsyncSaver writer thread is not running (closed or "
                "crashed); submitted steps would never reach disk")
        # synchronous device->host copy (cheap vs serialization), then
        # hand off to the writer thread.
        host = jax.tree.map(lambda x: jax.device_get(x), tree)
        self._q.put((step, host))

    def wait(self):
        self._q.join()
        self._raise_pending()

    def close(self):
        try:
            self.wait()
        finally:
            # shut the thread down even when the last save failed, so a
            # raising close() cannot leak the worker
            self._q.put(None)
            self._t.join()
