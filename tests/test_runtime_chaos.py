"""Chaos-engine + elastic-membership property suite.

The runtime contract pinned here: a :class:`~repro.runtime.chaos.
FaultInjector` schedule compiled through a :class:`~repro.runtime.
elastic.Membership` yields the exact ``(edge_weights, dev_weights,
mask)`` arrays the train step consumes, with

  * edge weights a probability distribution over the live pods,
  * the fail-open invariant (an all-dead fleet never zeroes the state),
  * straggler demotion bitwise-indistinguishable from a sampled-out
    client,
  * seeded schedules that are pure functions of the seed, and
  * restore-and-replay determinism (replaying a schedule prefix lands
    on the same membership as the uninterrupted pass).

Property tests run on plain numpy (fast); the two bitwise trajectory
pins run one tiny jitted cell each.
"""
import pathlib
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, str(pathlib.Path(__file__).parent / "helpers"))
import parity_harness as H  # noqa: E402

from repro.core.clients import ClientConfig  # noqa: E402
from repro.core.topology import single_device_topology  # noqa: E402
from repro.runtime import chaos, elastic, failures  # noqa: E402


def _seeded_member(pods, devs, k, seed):
    rng = np.random.default_rng(seed)
    cc = ClientConfig(count=k) if k > 1 else ClientConfig()
    return elastic.Membership(
        pods, devs, clients=cc,
        data_sizes=rng.integers(1, 100, (pods, devs)))


# ---------------------------------------------------------------------------
# Membership array invariants
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 3),
       st.integers(0, 2**31 - 1))
def test_edge_weights_sum_over_live_pods(pods, devs, k, seed):
    """edge_weights is a probability distribution concentrated on the
    live pods, for any churn state reachable through a seeded
    schedule."""
    m = _seeded_member(pods, devs, k, seed)
    inj = chaos.FaultInjector.seeded(seed, 12, pods, devs, k,
                                     client_rate=0.3, pod_rate=0.2,
                                     heartbeat_rate=0.2,
                                     straggler_rate=0.3)
    for arr in chaos.compile_schedule(inj, m, 12):
        assert np.isclose(arr.edge_weights.sum(), 1.0, atol=1e-6)
        assert (arr.edge_weights >= 0).all()
        assert (arr.mask >= 0).all() and (arr.mask <= 1).all()
        # a pod with zero cloud weight contributes no votes either
        dead = arr.edge_weights == 0
        assert (arr.mask[dead] == 0).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 3),
       st.integers(0, 2**31 - 1))
def test_fail_open_never_zeroes(pods, devs, k, seed):
    """Killing the ENTIRE fleet trips fail-open: every voter stays
    counted (all-ones mask, uniform pod weights) -- the runtime must
    never emit arrays that zero the model state."""
    m = _seeded_member(pods, devs, k, seed)
    for p in range(pods):
        m.mark_failed(p)
    arr = m.weights()
    assert (arr.mask == 1.0).all()
    assert np.isclose(arr.edge_weights.sum(), 1.0, atol=1e-6)
    assert (arr.edge_weights > 0).all()


def test_subquorum_pod_abstains_wholesale():
    """A pod below the vote quorum loses its cloud weight and its mask
    in one place (the single ``pod_ok`` application -- the old code
    multiplied it in twice), while the survivors renormalize."""
    m = elastic.Membership(2, 4, quorum=0.75,
                           data_sizes=np.array([[1., 1, 1, 1],
                                                [1., 1, 1, 1]]))
    m.mark_failed(0, 0)
    m.mark_failed(0, 1)           # 50% live < 75% quorum
    arr = m.weights()
    assert arr.edge_weights[0] == 0.0
    assert np.isclose(arr.edge_weights[1], 1.0)
    assert (arr.mask[0] == 0).all()
    # devices 2,3 of pod 0 are LIVE but sub-quorum: masked exactly once,
    # and the pod's dev shares carry no weight
    assert (arr.dev_weights[0] == 0).all()
    assert np.isclose(arr.dev_weights[1].sum(), 1.0)


def test_mask_granularity_follows_client_config():
    """Active ClientConfig -> client-granular [P, D, K] mask; default
    config -> legacy [P, D] device mask."""
    ma = elastic.Membership(2, 3, clients=ClientConfig(count=4)).weights()
    assert ma.mask.shape == (2, 3, 4)
    ml = elastic.Membership(2, 3).weights()
    assert ml.mask.shape == (2, 3)
    assert ma.dev_weights.shape == ml.dev_weights.shape == (2, 3)


def test_heartbeat_loss_is_swept():
    """A silent client ages past the timeout and loses its vote on the
    next sweep; a heartbeat (or recover) brings it back."""
    m = elastic.Membership(1, 2, clients=ClientConfig(count=2),
                           heartbeat_timeout=1.0)
    chaos.apply_event(m, chaos.ChaosEvent(0, "heartbeat", 0, 1, 0),
                      now=5.0)
    assert not m.live[0, 1, 0] and m.live[0, 1, 1]
    m.heartbeat(0, 1, now=6.0, client=0)
    assert m.live[0, 1, 0]


# ---------------------------------------------------------------------------
# Schedule determinism
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_seeded_schedule_is_pure(seed):
    """Same seed => the SAME schedule (event-for-event); a different
    seed diverges (for these rates, overwhelmingly likely)."""
    a = chaos.FaultInjector.seeded(seed, 40, 2, 2, 2)
    b = chaos.FaultInjector.seeded(seed, 40, 2, 2, 2)
    assert a == b and a.events == b.events
    c = chaos.FaultInjector.seeded(seed + 1, 40, 2, 2, 2)
    if a.events and c.events:
        assert a != c or a.events == c.events


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(1, 3), st.integers(0, 2**31 - 1),
       st.integers(1, 20))
def test_replay_matches_uninterrupted_prefix(pods, devs, seed, upto):
    """Restore-and-replay determinism at the membership layer:
    ``replay_membership(inj, m, upto)`` (a fresh membership + every
    event before ``upto``) emits the same arrays as the uninterrupted
    compile at step upto-1 -- so a driver that restores a checkpoint
    mid-schedule sees bitwise-identical membership inputs."""
    m = _seeded_member(pods, devs, 2, seed)
    inj = chaos.FaultInjector.seeded(seed, 24, pods, devs, 2,
                                     client_rate=0.3, heartbeat_rate=0.2,
                                     straggler_rate=0.3, pod_rate=0.15)
    arrays = chaos.compile_schedule(inj, m, 24)
    replayed = chaos.replay_membership(inj, m, upto)
    got = replayed.weights()
    want = arrays[upto - 1]
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)


def test_compile_schedule_leaves_caller_untouched():
    m = elastic.Membership(2, 2)
    inj = chaos.FaultInjector([chaos.ChaosEvent(0, "pod", 0)])
    chaos.compile_schedule(inj, m, 4)
    assert m.live.all()


def test_nan_fires_once_and_legacy_dict_schedule():
    """``nan_due`` is edge-triggered (the post-restore replay of the
    same step must not blow up again); the legacy ``{step: (kind, pod,
    dev)}`` dict form still builds a schedule."""
    inj = chaos.FaultInjector([chaos.ChaosEvent(5, "nan")])
    assert inj.nan_due(4) is False
    assert inj.nan_due(5) is True
    assert inj.nan_due(5) is False          # replay passes through
    legacy = failures.FaultInjector({6: ("device", 0, 0),
                                     9: ("recover", 0, 0)})
    assert legacy.at(6)[0].kind == "device"
    assert legacy.horizon == 10
    with pytest.raises(ValueError, match="kind"):
        chaos.ChaosEvent(0, "meteor")


# ---------------------------------------------------------------------------
# Bitwise trajectory pins (one tiny jitted cell each)
# ---------------------------------------------------------------------------


def test_demoted_straggler_equals_sampled_out_client():
    """Straggler demotion and a client kill take different runtime
    paths into the membership but the SAME abstention semantics out of
    it: identical compiled arrays, and a bitwise-identical model
    trajectory -- the demoted client is indistinguishable from one the
    participation sampler left out."""
    topo = single_device_topology()
    problem = H.make_problem(1, 1)
    cc = H.client_cfg(1, 1, 2, "full")
    m = elastic.Membership(1, 1, clients=cc)
    steps = problem["rounds"] * problem["t_e"] + 1
    demote = chaos.FaultInjector([chaos.ChaosEvent(2, "straggler",
                                                   0, 0, 1)])
    kill = chaos.FaultInjector([chaos.ChaosEvent(2, "client", 0, 0, 1)])
    arr_d = chaos.compile_schedule(demote, m, steps)
    arr_k = chaos.compile_schedule(kill, m, steps)
    for s in range(steps):
        for a, b in zip(arr_d[s], arr_k[s]):
            np.testing.assert_array_equal(a, b)
    ref, _ = H.run_hier_chaos(topo, problem, "dc_hier_signsgd",
                              clients=cc, arrays=arr_d)
    got, _ = H.run_hier_chaos(topo, problem, "dc_hier_signsgd",
                              clients=cc, arrays=arr_k)
    H.assert_trees_equal(ref, got, "straggler-vs-kill")


def test_detector_escalation_feeds_demotion():
    """End-to-end straggler escalation: the detector's per-client slow
    counter crosses ``patience`` and the resulting ``demote`` abstains
    the client in the emitted arrays."""
    det = failures.FailureDetector(failures.FailurePolicy(
        straggler_factor=2.0, patience=2))
    for _ in range(8):
        det.record_step(1.0)
    m = elastic.Membership(1, 2, clients=ClientConfig(count=2))
    for _ in range(2):
        slow = det.device_slow(0, 1, 9.0, client=0)
    assert slow
    m.demote(0, 1, 0)
    arr = m.weights()
    assert arr.mask[0, 1, 0] == 0.0 and arr.mask[0, 1, 1] == 1.0


# ---------------------------------------------------------------------------
# FailureDetector regressions (satellite fixes)
# ---------------------------------------------------------------------------


def test_may_restore_is_pure():
    """Regression: ``may_restore`` used to consume restore budget ON
    QUERY, so health checks silently burned the allowance.  It is now a
    pure query; only ``record_restore`` spends."""
    det = failures.FailureDetector(failures.FailurePolicy(max_restores=2))
    for _ in range(10):
        assert det.may_restore()            # querying never spends
    assert det.restores == 0
    det.record_restore()
    det.record_restore()
    assert not det.may_restore()
    assert det.restores == 2


def test_step_time_window_is_bounded_deque():
    """Regression: the step-time history is a bounded deque (the old
    list popped index 0 -- O(n) per step) and the median tracks the
    window, not all history."""
    det = failures.FailureDetector(failures.FailurePolicy(window=4))
    for t in [1.0] * 10 + [5.0] * 4:
        det.record_step(t)
    assert len(det.step_times) == 4
    assert det.median_step() == 5.0
