"""Virtual-client sweep benchmark: merged voter axis vs streamed loop.

Sweeps K clients per device x {merged, stream} on the cost-model MLP
(51018 params, the paper's EMNIST shape) and records per-step wall time
plus two memory accountings:

  * analytic peak LIVE sign-plane bytes of the local step -- merged
    materializes K int8 sign planes + K packed word planes at once
    (K * (n + n/8) bytes); the streamed sweep holds ONE client's packed
    words plus the persistent integer tally
    (n/8 + tally_itemsize * n bytes), independent of K;
  * the compiled step's ``memory_analysis()`` temp/argument bytes
    (empirical, backend permitting).

Merged rows whose estimated live gradient planes (K * n * 4 bytes of
f32 voter grads) exceed ``--max_live_mb`` are recorded as REFUSED
without compiling -- that is the regime the streamed mode exists for:
K=1024 streams on a single CPU device while merged would blow the
budget.  The acceptance contract (checked into BENCH_clients.json):
stream at K=1024 stays within 2x of the K=1 merged baseline in peak
live sign-plane bytes (unit weights at K=1024 need an int16 tally:
2.125n vs the baseline's 1.125n, ratio ~1.89).

  PYTHONPATH=src python benchmarks/bench_clients.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import clients as vclients
from repro.core import hier, votes
from repro.core.topology import single_device_topology

# the cost-model EMNIST MLP (benchmarks/cost_model.D_PARAMS)
DIN, HID, DOUT = 784, 64, 10
N_PARAMS = DIN * HID + HID + HID * DOUT + DOUT          # 51018

SPECS = {"w1": P(None, None), "b1": P(None),
         "w2": P(None, None), "b2": P(None)}

K_SWEEP = (4, 64, 256, 1024)
K_SWEEP_FAST = (4, 64)


def loss_fn(params, batch, rng):
    h = jnp.tanh(batch["x"] @ params["w1"] + params["b1"])
    pred = h @ params["w2"] + params["b2"]
    return jnp.mean((pred - batch["y"]) ** 2)


def init_params(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (DIN, HID)) * 0.05,
            "b1": jnp.zeros((HID,)),
            "w2": jax.random.normal(k2, (HID, DOUT)) * 0.05,
            "b2": jnp.zeros((DOUT,))}


def client_config(k: int, mode: str) -> vclients.ClientConfig:
    if k == 1:                      # the inactive legacy baseline
        return vclients.ClientConfig()
    return vclients.ClientConfig(count=k, participation="bernoulli",
                                 rate=0.5, seed=3, mode=mode)


def sign_plane_bytes(mode: str, k: int, weight_bound: int | None) -> int:
    """Analytic peak live sign-plane bytes of one local step."""
    n = N_PARAMS
    words_b = (n // 32 + (1 if n % 32 else 0)) * 4
    if mode == "merged":
        return k * n + k * words_b              # K int8 planes + K packed
    acc = jnp.dtype(votes.tally_dtype(weight_bound)).itemsize
    return words_b + acc * n                    # ONE packed plane + tally


def merged_live_grad_mb(k: int) -> float:
    """Estimated live f32 voter-gradient planes of the merged step."""
    return k * N_PARAMS * 4 / 2**20


def bench_one(topo, k: int, mode: str, iters: int, max_live_mb: float):
    cc = client_config(k, mode)
    bound = (cc.weight_bound(topo.pods, topo.devices_per_pod)
             if cc.active else None)
    row = {
        "mode": mode, "clients": k, "batch_per_device": k,
        "sign_plane_bytes": sign_plane_bytes(mode, k, bound),
        "refused": False, "reason": None,
    }
    if mode == "merged" and merged_live_grad_mb(k) > max_live_mb:
        row["refused"] = True
        row["reason"] = (f"estimated live voter grads "
                         f"{merged_live_grad_mb(k):.0f} MB > "
                         f"--max_live_mb {max_live_mb:.0f}")
        return row

    algo = hier.AlgoConfig(method="dc_hier_signsgd", transport="fused",
                           state_layout="flat", clients=cc,
                           compute_dtype=jnp.float32,
                           master_dtype=jnp.float32,
                           delta_dtype=jnp.float32)
    bundle = hier.ModelBundle(loss=loss_fn, compute_specs=SPECS,
                              master_specs=SPECS)
    # sync="never": the steady-state local step (the anchor pass is a
    # per-round cost, amortized 1/T_E; this bench prices the inner loop)
    init_fn, step = hier.make_hier_step(topo, algo, bundle, sync="never")
    state = jax.jit(init_fn)(init_params(jax.random.PRNGKey(0)),
                             jax.random.PRNGKey(1))
    p, d = topo.pods, topo.devices_per_pod
    b = k                                       # one row per client
    key = jax.random.PRNGKey(7)
    batch = {"train": {
        "x": jax.random.normal(key, (p, d, b, DIN)),
        "y": jax.random.normal(jax.random.fold_in(key, 1),
                               (p, d, b, DOUT))}}
    ew = jnp.ones((p,)) / p
    dw = jnp.ones((p, d)) / d
    mask = jnp.ones((p, d))

    jstep = jax.jit(step)
    lowered = jstep.lower(state, batch, ew, dw, mask)
    compiled = lowered.compile()
    try:
        ma = compiled.memory_analysis()
        row["temp_bytes"] = getattr(ma, "temp_size_in_bytes", None)
        row["argument_bytes"] = getattr(ma, "argument_size_in_bytes", None)
    except Exception as e:                       # backend-dependent
        row["memory_analysis_error"] = str(e)

    state, _ = jax.block_until_ready(jstep(state, batch, ew, dw, mask))
    t0 = time.perf_counter()
    for _ in range(iters):
        state, metrics = jstep(state, batch, ew, dw, mask)
    jax.block_until_ready(state)
    row["us_per_step"] = (time.perf_counter() - t0) / iters * 1e6
    row["loss"] = float(metrics["loss"])
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI profile: K in {4, 64}, fewer timed iters")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--max_live_mb", type=float, default=128.0,
                    help="live-memory budget; merged rows whose voter "
                         "grads exceed it are recorded as refused")
    ap.add_argument("--out", default=str(
        pathlib.Path(__file__).resolve().parents[1]
        / "BENCH_clients.json"))
    args = ap.parse_args()

    topo = single_device_topology()
    sweep = K_SWEEP_FAST if args.fast else K_SWEEP
    iters = args.iters or (2 if args.fast else 5)

    rows = [bench_one(topo, 1, "merged", iters, args.max_live_mb)]
    print("mode,clients,us_per_step,sign_plane_bytes,refused")
    for k in sweep:
        for mode in ("merged", "stream"):
            rows.append(bench_one(topo, k, mode, iters, args.max_live_mb))
    for r in rows:
        print(f"{r['mode']},{r['clients']},"
              f"{r.get('us_per_step', 0.0):.1f},"
              f"{r['sign_plane_bytes']},{r['refused']}")

    by = {(r["mode"], r["clients"]): r for r in rows}
    base = by[("merged", 1)]["sign_plane_bytes"]
    checks = {"merged_k1_sign_plane_bytes": base}
    top = max(sweep)
    if ("stream", top) in by:
        ratio = by[("stream", top)]["sign_plane_bytes"] / base
        checks[f"stream_k{top}_sign_plane_ratio"] = round(ratio, 3)
        checks["stream_within_2x_of_k1_merged"] = ratio <= 2.0
        checks[f"stream_k{top}_ran"] = not by[("stream", top)]["refused"]
    if ("merged", top) in by:
        checks[f"merged_k{top}_refused"] = by[("merged", top)]["refused"]
    report = {
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "n_params": N_PARAMS,
            "iters": iters,
            "max_live_mb": args.max_live_mb,
            "note": "dc_hier_signsgd/fused/flat local step (sync=never), "
                    "one row per client per device batch; sign-plane "
                    "bytes are the analytic peak live planes (merged: "
                    "K*(n + n/8); stream: n/8 + tally_itemsize*n).",
        },
        "rows": rows,
        "checks": checks,
    }
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out_path} (checks={checks})")


if __name__ == "__main__":
    main()
