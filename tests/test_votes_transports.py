"""Vote transports must be bit-identical and correct at P=D=1 (single dev).

The multi-device equivalence (8 host CPUs, 2x2x2 mesh) runs in a
subprocess -- see test_distributed.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import signs, votes
from repro.core.topology import single_device_topology


@pytest.fixture(scope="module")
def topo():
    return single_device_topology()


@pytest.mark.parametrize("leaf_shape", [(64,), (3, 64), (5, 7, 32)])
def test_transports_identical(topo, leaf_shape):
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5) + leaf_shape)
    s = signs.sgn(x)
    v1 = votes.vote_ar_int8(topo, s, None)
    v2 = votes.vote_ag_packed(topo, s, None, P(*([None] * len(leaf_shape))))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    v3 = votes.fused_sign_vote(topo, {"leaf": s.astype(jnp.float32)})["leaf"]
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v3))
    # oracle per pod
    for p in range(2):
        ref = signs.majority_vote(s[p].reshape(5, -1), axis=0)
        np.testing.assert_array_equal(
            np.asarray(v1[p]).reshape(-1), np.asarray(ref))


def test_transports_mask(topo):
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 128))
    s = signs.sgn(x)
    mask = jnp.asarray([[1, 1, 0, 1, 0, 1]], jnp.float32) > 0
    v1 = votes.vote_ar_int8(topo, s, mask)
    v2 = votes.vote_ag_packed(topo, s, mask, P(None))
    v3 = votes.fused_sign_vote(topo, {"leaf": s.astype(jnp.float32)},
                               mask=mask)["leaf"]
    ref = signs.majority_vote(s[0][np.asarray(mask[0])], axis=0)
    np.testing.assert_array_equal(np.asarray(v1[0]), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(v2[0]), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(v3[0]), np.asarray(ref))


def test_ar_int8_upcasts_beyond_127_voters(topo):
    """Regression: D > 127 used to wrap the int8 tally (129 unanimous +1
    voters summed to -127 -> vote -1)."""
    s = jnp.ones((1, 129, 64), jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(votes.vote_ar_int8(topo, s, None)), 1)
    rng = np.random.default_rng(3)
    s = jnp.asarray(rng.choice([-1, 1], size=(2, 200, 33)), jnp.int8)
    ref = np.stack([np.asarray(signs.majority_vote(s[p], axis=0))
                    for p in range(2)])
    np.testing.assert_array_equal(
        np.asarray(votes.vote_ar_int8(topo, s, None)), ref)
    # masked: only 100 of 200 voters count, tally still exact
    mask = jnp.asarray(rng.integers(0, 2, (2, 200)), jnp.float32) > 0.5
    got = votes.vote_ar_int8(topo, s, mask)
    for p in range(2):
        ref_p = signs.majority_vote(s[p][np.asarray(mask[p])], axis=0)
        np.testing.assert_array_equal(np.asarray(got[p]), np.asarray(ref_p))


def test_weighted_vote_transports_identical(topo):
    """Integer |D_qk| vote weights: all three transports compute the
    same weighted popcount as the signs-level oracle, and an edge whose
    whole quorum carries weight 0 abstains (vote 0)."""
    rng = np.random.default_rng(11)
    s = jnp.asarray(rng.choice([-1, 1], size=(3, 5, 64)), jnp.int8)
    w = jnp.asarray(rng.integers(0, 6, (3, 5)), jnp.int32)
    w = w.at[2].set(0)                      # pod 2: empty quorum
    bound = int(np.max(np.sum(np.asarray(w), axis=1)))
    v1 = votes.vote_ar_int8(topo, s, w, weight_bound=bound)
    v2 = votes.vote_ag_packed(topo, s, w, P(None))
    v3 = votes.fused_sign_vote(topo, {"leaf": s.astype(jnp.float32)},
                               mask=w)["leaf"]
    for p in range(3):
        ref = signs.majority_vote(s[p], w[p], axis=0)
        np.testing.assert_array_equal(np.asarray(v1[p]), np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(v2[p]), np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(v3[p]), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(v1[2]), 0)


def test_weighted_tally_promotes_beyond_int8(topo):
    """Regression (boundary): the int tally promotes on sum(w), not on
    the voter count -- two voters of weight 64 are a 128-range tally
    that would wrap int8 (128 -> -128 -> vote -1)."""
    s = jnp.ones((1, 2, 64), jnp.int8)      # both vote +1
    w = jnp.asarray([[64, 64]], jnp.int32)  # sum(w) = 128 > 127
    np.testing.assert_array_equal(
        np.asarray(votes.vote_ar_int8(topo, s, w, weight_bound=128)), 1)
    # at the boundary sum(w) = 127 the tally still rides int8 exactly
    w127 = jnp.asarray([[64, 63]], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(votes.vote_ar_int8(topo, s, w127, weight_bound=127)), 1)
    assert votes._tally_acc(127) == jnp.int8
    assert votes._tally_acc(128) == jnp.int16
    assert votes._tally_acc(32768) == jnp.int32
    # integer weights WITHOUT a bound must fail loudly -- the
    # voter-count default would silently re-open the int8 wrap
    with pytest.raises(ValueError, match="weight_bound"):
        votes.vote_ar_int8(topo, s, w)
    # randomized: mixed signs, weights large enough to break int8
    rng = np.random.default_rng(5)
    s = jnp.asarray(rng.choice([-1, 1], size=(2, 9, 33)), jnp.int8)
    w = jnp.asarray(rng.integers(0, 40, (2, 9)), jnp.int32)
    bound = int(np.max(np.sum(np.asarray(w), axis=1)))
    got = votes.vote_ar_int8(topo, s, w, weight_bound=bound)
    for p in range(2):
        ref = signs.majority_vote(s[p], w[p], axis=0)
        np.testing.assert_array_equal(np.asarray(got[p]), np.asarray(ref))


def test_fused_vote_many_voters(topo):
    """D > 64 takes _popcount_vote_words's reduction branch (the voter
    unroll is capped) -- results must still match the oracle and the
    int-tally transport, masked and unmasked."""
    rng = np.random.default_rng(7)
    s = jnp.asarray(rng.choice([-1, 1], size=(2, 130, 96)), jnp.int8)
    tree = {"leaf": s.astype(jnp.float32)}
    got = votes.fused_sign_vote(topo, tree)["leaf"]
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(votes.vote_ar_int8(topo, s, None)))
    mask = jnp.asarray(rng.integers(0, 2, (2, 130)), jnp.float32) > 0.5
    got = votes.fused_sign_vote(topo, tree, mask=mask)["leaf"]
    for p in range(2):
        ref_p = signs.majority_vote(s[p][np.asarray(mask[p])], axis=0)
        np.testing.assert_array_equal(np.asarray(got[p]), np.asarray(ref_p))


def test_packed_dispatch_fallback(topo):
    """Leaves with minor dim % 32 != 0 fall back to int8 (same result)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 33))
    s = signs.sgn(x)
    out = votes.majority_vote_dev(topo, s, None, "ag_packed", P(None))
    ref = signs.majority_vote(s[0], axis=0)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref))


def test_pod_weighted_average(topo):
    v = jnp.stack([jnp.full((4,), 1.0), jnp.full((4,), 3.0)])
    w = jnp.asarray([0.25, 0.75])
    out = votes.pod_weighted_average(topo, v, w)
    np.testing.assert_allclose(np.asarray(out), 2.5)
    assert out.shape == v.shape  # broadcast back to every pod


def test_weighted_mean_dev(topo):
    g = jnp.arange(12, dtype=jnp.float32).reshape(1, 3, 4)
    w = jnp.asarray([[0.5, 0.25, 0.25]])
    out = votes.weighted_mean_dev(topo, g, w)
    ref = 0.5 * g[0, 0] + 0.25 * g[0, 1] + 0.25 * g[0, 2]
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref))


# -- streamed-tally machinery (ClientConfig.mode="stream") ------------------

def test_streamed_tally_dtype_matches_vote_ar_int8_promotion():
    """The streamed accumulator promotes EXACTLY where the merged int
    tally (``vote_ar_int8`` / ``_tally_acc``) does: on the weight bound
    sum(w), not on the client count -- 127 rides int8, 128 promotes to
    int16, 32767 still rides int16, 32768 promotes to int32."""
    assert votes.tally_dtype(127) == jnp.int8
    assert votes.tally_dtype(128) == jnp.int16
    assert votes.tally_dtype(32767) == jnp.int16
    assert votes.tally_dtype(32768) == jnp.int32
    for bound in (1, 2, 127, 128, 129, 255, 32767, 32768, 10**6):
        assert votes.tally_dtype(bound) == votes._tally_acc(bound)


def test_streamed_tally_no_wrap_at_promotion_boundaries():
    """Unanimous +1 clients whose weights sum to the boundary: the
    promoted dtype carries the tally exactly (an int8 tally would wrap
    128 unanimous +1 weight to -128 -> vote -1)."""
    for weights, bound in (((64, 63), 127), ((64, 64), 128),
                           ((16384, 16383), 32767), ((16384, 16384), 32768)):
        dt = votes.tally_dtype(bound)
        tally = jnp.zeros((1, 1, 64), dt)
        s = jnp.ones((1, 1, 64), jnp.int8)
        for w in weights:
            tally = votes.tally_add_signs(tally, s,
                                          jnp.full((1, 1), w, jnp.int32))
        assert tally.dtype == dt
        assert int(np.asarray(tally).max()) == sum(weights)  # no wrap
        vote = votes.tally_vote(jnp.sum(tally.astype(jnp.int32), axis=1),
                                jnp.asarray([sum(weights)], jnp.int32))
        np.testing.assert_array_equal(np.asarray(vote), 1)


def test_streamed_deferred_threshold_tie_and_abstain():
    """Weighted tie resolves sgn(0) = +1 after the deferred threshold
    (t = 0 <=> merged's 2*pos == n_eff), and an empty quorum abstains."""
    s_pos = jnp.ones((1, 1, 32), jnp.int8)
    tally = jnp.zeros((1, 1, 32), jnp.int8)
    tally = votes.tally_add_signs(tally, s_pos, jnp.full((1, 1), 3))
    tally = votes.tally_add_signs(tally, -s_pos, jnp.full((1, 1), 3))
    t_edge = jnp.sum(tally.astype(jnp.int32), axis=1)
    vote = votes.tally_vote(t_edge, jnp.asarray([6], jnp.int32))
    np.testing.assert_array_equal(np.asarray(vote), 1)   # sgn(0) = +1
    # merged reference on the same two voters
    s2 = jnp.concatenate([s_pos, -s_pos], axis=1)
    topo = single_device_topology()
    merged = votes.vote_ar_int8(topo, s2, jnp.asarray([[3, 3]]),
                                weight_bound=6)
    np.testing.assert_array_equal(np.asarray(vote), np.asarray(merged))
    # empty quorum: zero weights -> n_eff 0 -> abstain (vote 0)
    abstain = votes.tally_vote(jnp.zeros((1, 32), jnp.int32),
                               jnp.asarray([0], jnp.int32))
    np.testing.assert_array_equal(np.asarray(abstain), 0)


def test_streamed_tally_matches_merged_weighted_vote(topo):
    """Client-at-a-time tally accumulation (both the int8-sign and the
    packed-words entry points) reproduces the merged weighted popcount
    bitwise, including zero-weight (abstaining) clients."""
    rng = np.random.default_rng(11)
    p, d, k, n = 2, 3, 5, 96
    s = jnp.asarray(rng.choice([-1, 1], size=(p, d * k, n)), jnp.int8)
    w = jnp.asarray(rng.integers(0, 4, (p, d * k)), jnp.int32)
    bound = int(np.asarray(w).reshape(p, d, k).sum(axis=2).max())
    merged = votes.vote_ar_int8(topo, s, w, weight_bound=bound)

    s3 = s.reshape(p, d, k, n)
    w3 = w.reshape(p, d, k)
    dt = votes.tally_dtype(bound)
    tally_s = jnp.zeros((p, d, n), dt)
    tally_w = jnp.zeros((p, d, n), dt)
    for c in range(k):
        s_c = s3[:, :, c]
        tally_s = votes.tally_add_signs(tally_s, s_c, w3[:, :, c])
        words = jax.vmap(jax.vmap(signs.pack_signs))(s_c)
        tally_w = votes.tally_accumulate_words(words, w3[:, :, c], tally_w)
    np.testing.assert_array_equal(np.asarray(tally_s), np.asarray(tally_w))
    n_eff = jnp.sum(w.astype(jnp.int32), axis=1)
    vote = votes.tally_vote(jnp.sum(tally_s.astype(jnp.int32), axis=1),
                            n_eff)
    np.testing.assert_array_equal(np.asarray(vote), np.asarray(merged))
