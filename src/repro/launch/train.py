"""End-to-end hierarchical training driver.

Wires together: config -> model -> DC-HierSignSGD step -> synthetic data
stream -> elastic membership -> async checkpointing -> failure recovery.
Runs the production configs on a real mesh, and the reduced smoke configs
on CPU (the integration tests and examples call ``run_training`` with a
small Topology).

CLI (reduced-scale CPU run):
  PYTHONPATH=src python -m repro.launch.train --arch gemma3_1b --smoke \
      --steps 30 --t_e 5 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import store
from repro.checkpoint.async_ckpt import AsyncSaver
from repro.core import clients as vclients
from repro.core import hier, schedule, votes
from repro.core.topology import Topology, single_device_topology
from repro.data import synthetic
from repro.models import build
from repro.runtime import chaos as chaos_mod
from repro.runtime import elastic, failures


@dataclasses.dataclass
class RunCfg:
    steps: int = 50
    batch_per_device: int = 4
    seq_len: int = 128
    ckpt_dir: str | None = None
    ckpt_every: int = 20
    log_every: int = 5
    hetero: float = 1.0
    alpha_client: float | None = None
    edge_assign: str = "fixed"
    seed: int = 0


def run_training(cfg, topo: Topology, algo: hier.AlgoConfig, run: RunCfg,
                 fault_injector: failures.FaultInjector | None = None,
                 on_metrics: Callable[[int, dict], None] | None = None):
    """Returns (final_state, history).  Deterministic given seeds."""
    built = build.build_model(cfg, topo)
    init_fn, step_fn = hier.make_hier_step(topo, algo, built.bundle)
    jstep = jax.jit(step_fn, donate_argnums=(0,))

    params = built.init_params(jax.random.PRNGKey(run.seed))
    # init under jit: masters constrained to uneven model-sharded specs
    # (odd vocab/head extents on a TP mesh) only exist as jit-produced
    # arrays -- eager placement of uneven shardings is unsupported
    state = jax.jit(init_fn)(params, jax.random.PRNGKey(run.seed + 1))

    stream = synthetic.make_stream(synthetic.LMStreamCfg(
        vocab=cfg.vocab, seq_len=run.seq_len,
        batch_per_device=run.batch_per_device, pods=topo.pods,
        devices_per_pod=topo.devices_per_pod, seed=run.seed,
        hetero=run.hetero, clients_per_device=algo.clients.count,
        alpha_client=run.alpha_client, edge_assign=run.edge_assign,
        frames=cfg.encoder_frames if cfg.family in ("encdec", "audio")
        else 0,
        frontend_dim=cfg.frontend_dim, n_patches=cfg.n_patches,
        d_model=cfg.d_model))

    # membership speaks the step's own vocabulary: with an active
    # ClientConfig the mask it emits is client-granular [P, D, K], and
    # every churn event is a VALUE change of fixed-shape arrays (no
    # retrace -- pinned by the parity matrix's zero-recompilation test)
    member = elastic.Membership(topo.pods, topo.devices_per_pod,
                                clients=algo.clients)
    detector = failures.FailureDetector()
    saver = AsyncSaver(run.ckpt_dir) if run.ckpt_dir else None

    # resume if a checkpoint exists
    start = 0
    if run.ckpt_dir:
        restored = store.restore_latest(run.ckpt_dir, state)
        if restored is not None:
            start, state = restored
            print(f"[train] resumed from step {start}")

    history = []
    step = start
    while step < run.steps:
        if fault_injector is not None:
            # events at step s apply BEFORE step s runs -- the same
            # semantics chaos.compile_schedule gives the parity tests
            chaos_mod.apply_events(member, fault_injector.at(step),
                                   now=float(step))
        arrays = member.weights()
        batch = {"train": stream(step)}
        t0 = time.time()
        state, metrics = jstep(state, batch,
                               jnp.asarray(arrays.edge_weights),
                               jnp.asarray(arrays.dev_weights),
                               jnp.asarray(arrays.mask))
        loss = float(metrics["loss"])
        detector.record_step(time.time() - t0)
        if fault_injector is not None and fault_injector.nan_due(step):
            loss = float("nan")        # injected numeric blow-up

        if not detector.check_loss(loss):
            if saver:
                saver.wait()
            restored = (store.restore_latest(run.ckpt_dir, state)
                        if run.ckpt_dir else None)
            if restored is None or not detector.may_restore():
                raise RuntimeError(
                    f"non-finite loss at step {step}, no checkpoint")
            detector.record_restore()   # may_restore() is a pure query
            step, state = restored
            if fault_injector is not None:
                # membership replays from the schedule so the replayed
                # steps see the same arrays as the first pass
                member = chaos_mod.replay_membership(fault_injector,
                                                     member, step)
            print(f"[train] non-finite loss; restored step {step}")
            continue

        history.append({"step": step, "loss": loss,
                        "live": float(np.mean(member.live))})
        if on_metrics:
            on_metrics(step, metrics)
        if run.log_every and step % run.log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"mu {float(metrics['mu']):.2e} "
                  f"live {member.live.mean():.2f}")
        step += 1
        if saver and step % run.ckpt_every == 0:
            saver.submit(step, state)
    if saver:
        saver.submit(step, state)
        saver.close()
    return state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--t_e", type=int, default=5)
    ap.add_argument("--method", default="dc_hier_signsgd",
                    choices=hier.ALL_METHODS)
    ap.add_argument("--transport", default="ag_packed",
                    choices=votes.SIGN_TRANSPORTS)
    ap.add_argument("--state_layout", default="tree",
                    choices=["tree", "flat"],
                    help="flat: master params live as the core.flatbuf "
                         "buffer (whole-model fused update)")
    ap.add_argument("--mu", type=float, default=1e-3)
    ap.add_argument("--rho", type=float, default=0.2)
    ap.add_argument("--cloud_period", type=int, default=2,
                    help="mtgc only: rounds between cloud-timescale eta "
                         "refreshes (the edge-timescale gamma refreshes "
                         "every round)")
    ap.add_argument("--cloud_overlap", default="sync",
                    choices=list(schedule.CLOUD_OVERLAP_MODES),
                    help="cloud sync schedule: sync = issue and commit "
                         "the cross-pod aggregate at the same round "
                         "boundary (the paper's barrier); overlap = "
                         "commit one boundary later, hiding the cloud "
                         "round-trip behind a round of local stepping "
                         "(staged agg_next slot; replicated regime only)")
    ap.add_argument("--clients_per_device", type=int, default=1,
                    help="K virtual clients per data slice (the device "
                         "batch is carved into K per-client shards)")
    ap.add_argument("--client_mode", default="merged",
                    choices=list(vclients.CLIENT_MODES),
                    help="merged: widen the voter axis to D*K; stream: "
                         "loop clients inside the step in O(model/32 + "
                         "tally) memory (bitwise identical)")
    ap.add_argument("--alpha_client", type=float, default=None,
                    help="intra-edge Dirichlet concentration: each "
                         "virtual client samples from its own tilted "
                         "unigram (None/inf = the exact legacy "
                         "within-edge IID stream)")
    ap.add_argument("--edge_assign", default="fixed",
                    choices=list(synthetic.cluster.EDGE_ASSIGN_MODES),
                    help="client->edge placement: fixed = topology "
                         "order; random = seeded balanced scatter; "
                         "clustered = deterministic signature "
                         "clustering (requires --clients_per_device>1 "
                         "and --alpha_client)")
    ap.add_argument("--participation", default="full",
                    choices=list(vclients.PARTICIPATION_MODES),
                    help="per-round client sampling (pinned to "
                         "(seed, round); bernoulli/fixed use --participation_rate)")
    ap.add_argument("--participation_rate", type=float, default=1.0)
    ap.add_argument("--participation_seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="run under a seeded fault schedule "
                         "(runtime.chaos.FaultInjector.seeded: client/"
                         "pod kills, heartbeat loss, straggler "
                         "demotion, recoveries -- same seed, same "
                         "schedule); nan-loss recovery needs --ckpt")
    ap.add_argument("--multi_pod", action="store_true",
                    help="use the production 2x16x16 mesh")
    args = ap.parse_args()

    # surface the carve constraint and the scenario axes as clean CLI
    # errors instead of jit-time tracebacks (clustered assignment is
    # rejected here when the clients carve is inactive)
    try:
        vclients.validate_batch_carve(args.batch, args.clients_per_device,
                                      flag="clients_per_device")
        synthetic.validate_scenario(synthetic.LMStreamCfg(
            vocab=2, seq_len=args.seq, batch_per_device=args.batch,
            pods=1, devices_per_pod=1,
            clients_per_device=args.clients_per_device,
            alpha_client=args.alpha_client, edge_assign=args.edge_assign))
    except ValueError as e:
        ap.error(str(e))

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    # validate the schedule x regime combination up front: a clean CLI
    # error beats the make_hier_step ValueError's jit-time traceback
    if args.cloud_overlap == "overlap" and cfg.param_mode == "fsdp":
        ap.error(f"--cloud_overlap=overlap requires the replicated "
                 f"regime, but --arch {args.arch} uses param_mode='fsdp' "
                 f"(the staged in-flight aggregate is a whole-model "
                 f"master snapshot the FSDP lift never materializes)")
    if args.multi_pod:
        from repro.launch import mesh as mesh_mod
        topo = mesh_mod.make_topology(multi_pod=True)
    else:
        topo = single_device_topology()
    algo = hier.AlgoConfig(method=args.method, mu=args.mu, rho=args.rho,
                           cloud_period=args.cloud_period,
                           cloud_overlap=args.cloud_overlap,
                           t_e=args.t_e, transport=args.transport,
                           state_layout=args.state_layout,
                           clients=vclients.ClientConfig(
                               count=args.clients_per_device,
                               participation=args.participation,
                               rate=args.participation_rate,
                               seed=args.participation_seed,
                               mode=args.client_mode),
                           compute_dtype=jnp.float32 if args.smoke
                           else jnp.bfloat16)
    run = RunCfg(steps=args.steps, batch_per_device=args.batch,
                 seq_len=args.seq, ckpt_dir=args.ckpt,
                 alpha_client=args.alpha_client,
                 edge_assign=args.edge_assign)
    injector = None
    if args.chaos is not None:
        injector = chaos_mod.FaultInjector.seeded(
            args.chaos, args.steps, topo.pods, topo.devices_per_pod,
            algo.clients.count)
        print(f"[train] chaos seed {args.chaos}: "
              f"{len(injector.events)} scheduled events")
    _, history = run_training(cfg, topo, algo, run,
                              fault_injector=injector)
    print(f"[train] done: loss {history[0]['loss']:.4f} -> "
          f"{history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
